//! The pair-job execution engine: the single owner of
//! partition → schedule → solve → reduce for *both* front-ends.
//!
//! [`run_serial`] drives a [`PairSolver`] on the calling thread in the
//! paper's schedule order (the `decomp::decomposed_mst` reference path);
//! [`execute_pooled`] drives a `std::thread` worker pool with cost-LPT
//! dealing + idle stealing over the same plan, with every scatter/gather
//! charged to a [`Transport`] (the `coordinator::run_distributed` path —
//! the simulated [`NetSim`](crate::net::NetSim) byte model by default, or
//! real TCP links via [`execute_pooled_remote`], where each pool thread
//! drives a remote `demst worker` process through a
//! [`RemoteLink`]). Per-phase timings and evaluation counters land in
//! [`RunMetrics`].
//!
//! Pooled flow, bipartite-merge kernel:
//!
//! ```text
//! phase local-MST:  pool over partitions   — MST(S_k) once each, cached
//! phase pair:       pool over pair jobs    — filtered Prim per (S_i, S_j)
//! phase reduce:     leader                 — streaming ⊕ or batch Kruskal
//! ```
//!
//! The dense kernel skips the first phase and solves each pair with a full
//! d-MST over the gathered union, exactly as before the refactor.
//!
//! ## Remote execution: pipelined and elastic
//!
//! The remote driver ([`execute_pooled_remote`]) keeps up to
//! `cfg.pipeline_window` `PairAssign` frames outstanding per link before
//! reading the matching replies, overlapping scatter with remote compute
//! (window 1 restores the strict rendezvous; frames per link stay FIFO, so
//! window size cannot change which bytes travel — only when).
//!
//! When a worker's link dies mid-run, its claimed-but-undelivered jobs
//! (the in-flight window, plus — in reduce mode — every job folded into
//! its never-gathered local tree) go back to the [`JobQueue`]'s return
//! lane, its unclaimed deck is abandoned to the lane, and the surviving
//! fleet drains the lane; every pair job is still *recorded exactly once*
//! at the leader, so the final tree is bit-identical to a failure-free
//! run. `RunMetrics::{worker_failures, jobs_reassigned}` witness the
//! recovery.
//!
//! ## Sharded execution: the leader never holds vectors
//!
//! Under [`execute_pooled_sharded`] the engine runs with **no dataset at
//! all**: the plan comes from a shard manifest, every subset's vectors are
//! resident on the workers that loaded them from local shard files
//! (advertised in the versioned handshake, seeding the resident-set model), and
//! scheduling is restricted to workers holding *both* subsets of a job
//! ([`ExecPlan::affinity_for_holders`]). Phase 1 dispatches header-only
//! `LocalAssign` frames; pair scatter ships at most cached local *trees*
//! (edges, never vectors). `RunMetrics::leader_ingest_bytes` — the vector
//! payload that passed through the leader — is 0 by construction, with
//! `shard_local_bytes` accounting what the fleet loaded from disk instead.

use super::pair_kernel::{
    subset_mst, BipartiteCtx, BipartitePairSolver, DensePairSolver, LocalMstCache, PairSolver,
    Shipment, SolverFinal,
};
use super::plan::{AffinityPlan, ExecPlan};
use super::scheduler::JobQueue;
use crate::config::{PairKernelChoice, RunConfig};
use crate::coordinator::messages::{job_wire_bytes, Message, HEADER_BYTES};
use crate::coordinator::metrics::RunMetrics;
use crate::data::Dataset;
use crate::decomp::reduction::{reduce_trees_with, tree_merge, StreamReducer};
use crate::decomp::{pair_count, DecompConfig, DecompOutput, PairJob};
use crate::geometry::simd::{self, Isa};
use crate::geometry::CountingMetric;
use crate::graph::Edge;
use crate::mst::kruskal;
use crate::net::remote::RemoteLink;
use crate::net::{Direction, TcpTransport, Transport};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Resolve the worker count: explicit, else one per pair job capped at the
/// machine's parallelism.
pub fn resolve_workers(cfg: &RunConfig) -> usize {
    let jobs = pair_count(cfg.parts).max(1);
    if cfg.workers > 0 {
        cfg.workers.min(jobs)
    } else {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
        jobs.min(cores)
    }
}

/// Output of a serial engine run.
pub struct SerialRun {
    /// the exact global MSF
    pub mst: Vec<Edge>,
    /// total edges across all pair trees (the `O(|V|·|P|)` gather payload)
    pub union_edges: usize,
    /// per-pair trees in schedule order, if requested
    pub pair_trees: Vec<Vec<Edge>>,
    /// pair jobs executed
    pub jobs: usize,
}

/// Drive `solver` over the plan's jobs on the calling thread (schedule
/// order), then take the sparse MST of the union.
pub fn run_serial(
    n: usize,
    plan: &ExecPlan,
    solver: &mut dyn PairSolver,
    keep_pair_trees: bool,
) -> SerialRun {
    let mut union_edges: Vec<Edge> = Vec::new();
    let mut pair_trees = Vec::new();
    for job in &plan.jobs {
        let tree = solver.solve(plan, job);
        union_edges.extend_from_slice(&tree);
        if keep_pair_trees {
            pair_trees.push(tree);
        }
    }
    let union_count = union_edges.len();
    let mst = kruskal(n, &union_edges);
    SerialRun { mst, union_edges: union_count, pair_trees, jobs: plan.n_jobs() }
}

/// Serial decomposed MST with the bipartite-merge pair kernel: local MSTs
/// cached once, pair jobs solved by filtered Prim. Returns the same
/// [`DecompOutput`] as the dense reference path, with `dist_evals` =
/// `Σ_k |S_k|(|S_k|-1)/2 + Σ_{i<j} |S_i|·|S_j| = n(n-1)/2` exactly.
pub fn decomposed_mst_bipartite(
    ds: &Dataset,
    cfg: &DecompConfig,
    kind: crate::geometry::MetricKind,
) -> DecompOutput {
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    let ctx = BipartiteCtx::new(ds, kind);
    let cache = LocalMstCache::build_serial(ds, &ctx, &plan.parts);
    let mut solver = BipartitePairSolver::new(ds, &ctx, &cache);
    let run = run_serial(ds.n, &plan, &mut solver, cfg.keep_pair_trees);
    DecompOutput {
        mst: run.mst,
        union_edges: run.union_edges,
        dist_evals: cache.evals + solver.dist_evals(),
        jobs: run.jobs,
        pair_trees: run.pair_trees,
        part_sizes: plan.part_sizes(),
    }
}

/// Output of a pooled engine run.
pub struct PooledRun {
    pub mst: Vec<Edge>,
    pub metrics: RunMetrics,
    pub workers: usize,
}

/// What of one subset the executing worker already holds under the
/// resident-set model. On leader-resident runs vectors and cached tree
/// always travel (and are marked) together, reproducing the historical
/// single-flag model byte-for-byte; on sharded runs vectors are seeded
/// from the handshake advertisements while trees become resident only
/// where phase 1 built (or later shipped) them.
#[derive(Clone, Copy, Debug, Default)]
struct Held {
    vecs: bool,
    tree: bool,
}

/// Shared elastic-fleet bookkeeping: which links died, how many jobs were
/// recorded at the leader, and the run-level abort latch.
struct Fleet {
    dead: Vec<AtomicBool>,
    /// reduce mode: worker's shutdown rendezvous succeeded — its remotely
    /// ⊕-folded results are durably at the leader
    finished: Vec<AtomicBool>,
    done_jobs: AtomicUsize,
    expected_jobs: usize,
    failures: AtomicU32,
    reassigned: AtomicU32,
    abort: AtomicBool,
}

impl Fleet {
    fn new(workers: usize, expected_jobs: usize) -> Self {
        Self {
            dead: (0..workers).map(|_| AtomicBool::new(false)).collect(),
            finished: (0..workers).map(|_| AtomicBool::new(false)).collect(),
            done_jobs: AtomicUsize::new(0),
            expected_jobs,
            failures: AtomicU32::new(0),
            reassigned: AtomicU32::new(0),
            abort: AtomicBool::new(false),
        }
    }

    // Coordination flags use SeqCst: the elastic gates (`complete`,
    // `lower_all_settled`, `stranded`) read *combinations* of these
    // atomics, and a relaxed reordering between e.g. `done_jobs -= k` and
    // `dead[w] = true` would let a peer observe "complete and settled"
    // mid-failover and disperse with recoverable jobs stranded.

    fn alive(&self) -> Vec<bool> {
        self.dead.iter().map(|d| !d.load(Ordering::SeqCst)).collect()
    }

    fn complete(&self) -> bool {
        self.done_jobs.load(Ordering::SeqCst) >= self.expected_jobs
    }

    fn aborted(&self) -> bool {
        self.abort.load(Ordering::SeqCst)
    }

    /// Mark `w` dead. Call only **after** every recovery side effect of
    /// the failure (done-count rollback, return-lane pushes) is in place:
    /// peers treat a dead worker as settled, so the flag must be the last
    /// thing they can observe.
    fn fail_worker(&self, w: usize) {
        self.dead[w].store(true, Ordering::SeqCst);
        self.failures.fetch_add(1, Ordering::Relaxed);
    }
}

/// The pooled engine over the simulated transport: worker threads claim
/// jobs from per-worker affinity decks (cost-LPT within each deck, idle
/// stealing as fallback; one shared LPT queue when `cfg.affinity` is off);
/// the leader gathers trees (streaming or buffered) and finishes the
/// reduction. All traffic is charged to `net` — under the resident-set
/// model only payload the executing worker is missing, with the dense
/// model's difference recorded in `RunMetrics::scatter_saved_bytes`.
pub fn execute_pooled(
    ds: &Dataset,
    cfg: &RunConfig,
    net: &dyn Transport,
) -> anyhow::Result<PooledRun> {
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    execute_pooled_inner(Some(ds), ds.n, ds.d, cfg, net, None, plan)
}

/// The identical pooled engine run against **remote worker processes**:
/// pool thread `w` drives remote worker `w` through a [`RemoteLink`] over
/// `tcp`'s socket, with up to `cfg.pipeline_window` jobs in flight per
/// link. [`Transport::charge`] no-ops on the TCP transport — the counters
/// are fed by the actual encoded frames, which equal the modeled charges
/// byte-for-byte because [`Message::wire_bytes`] is computed from the real
/// wire encoding.
///
/// `plan` is the **same plan the handshake announced**: the caller
/// ([`crate::net::launch::serve`]) partitions once, tells every worker the
/// partition layout in its `Setup` frame, and hands the identical plan
/// here — so the section lengths workers derive for `PairAssign` frames
/// can never drift from the jobs the engine actually ships.
pub fn execute_pooled_remote(
    ds: &Dataset,
    cfg: &RunConfig,
    tcp: &TcpTransport,
    plan: ExecPlan,
) -> anyhow::Result<PooledRun> {
    execute_pooled_inner(Some(ds), ds.n, ds.d, cfg, tcp, Some(tcp), plan)
}

/// The sharded pooled engine: same remote execution, but the leader holds
/// **no vectors** — `plan` comes from a shard manifest (`n`/`d` likewise)
/// and every subset is resident on the workers whose handshake advertised
/// it. Scheduling is restricted to workers holding both subsets of a job;
/// pair scatter ships at most cached local trees.
pub fn execute_pooled_sharded(
    cfg: &RunConfig,
    tcp: &TcpTransport,
    plan: ExecPlan,
    n: usize,
    d: usize,
) -> anyhow::Result<PooledRun> {
    execute_pooled_inner(None, n, d, cfg, tcp, Some(tcp), plan)
}

fn execute_pooled_inner(
    ds: Option<&Dataset>,
    n: usize,
    d: usize,
    cfg: &RunConfig,
    net: &dyn Transport,
    remote: Option<&TcpTransport>,
    plan: ExecPlan,
) -> anyhow::Result<PooledRun> {
    let t_start = Instant::now();
    let sharded = ds.is_none();
    debug_assert!(!sharded || remote.is_some(), "sharded runs are remote by definition");
    let n_workers = resolve_workers(cfg);
    if let Some(tcp) = remote {
        anyhow::ensure!(
            tcp.len() == n_workers,
            "transport holds {} worker links but the plan resolves to {n_workers} workers",
            tcp.len()
        );
    }
    let counters = net.counters();
    let p = plan.parts.len();

    // Sharded residency: which subsets each worker's handshake advertised,
    // and the vector payload the fleet loaded from local shard files
    // instead of receiving over the wire (per worker copy — replicated
    // shards count once per replica, they were each read from disk).
    let holders: Option<Vec<Vec<bool>>> = if sharded {
        let tcp = remote.expect("sharded implies remote");
        let mut holders = vec![vec![false; p]; n_workers];
        for (w, row) in holders.iter_mut().enumerate() {
            for &k in tcp.advertised(w) {
                let k = k as usize;
                anyhow::ensure!(
                    k < p,
                    "worker {w} advertised shard {k} but the manifest has {p} shards"
                );
                row[k] = true;
            }
        }
        Some(holders)
    } else {
        None
    };
    let shard_local_bytes: u64 = holders.as_ref().map_or(0, |h| {
        h.iter()
            .flat_map(|row| row.iter().enumerate())
            .filter(|&(_, &held)| held)
            .map(|(k, _)| crate::net::wire::vectors_payload_bytes(plan.parts[k].len(), d))
            .sum()
    });

    // Subset-affinity routing + resident-set byte model (cfg.affinity):
    // each subset gets an anchor worker, jobs land on the anchor of their
    // larger subset, and each worker remembers which subsets (vectors +
    // cached tree) it already holds — residency persists from the local-MST
    // phase into the pair phase, and only the *missing* payload is charged.
    // Sharded runs use the holder-constrained variant and a capability mask.
    let (affinity, caps): (Option<AffinityPlan>, Option<Vec<Vec<bool>>>) =
        if let Some(h) = &holders {
            let (aff, caps) = plan.affinity_for_holders(h)?;
            (Some(aff), Some(caps))
        } else {
            (cfg.affinity.then(|| plan.affinity(n_workers)), None)
        };
    let residents: Vec<Mutex<Vec<Held>>> =
        (0..n_workers).map(|_| Mutex::new(vec![Held::default(); p])).collect();
    if let Some(h) = &holders {
        for (w, row) in h.iter().enumerate() {
            let mut res = residents[w].lock().unwrap();
            for (k, &held) in row.iter().enumerate() {
                res[k].vecs = held;
            }
        }
    }
    let scatter_saved = AtomicU64::new(0);
    let leader_ingest = AtomicU64::new(0);
    let fleet = Fleet::new(n_workers, plan.n_jobs());

    let panel_settings = cfg.panel_settings();
    let mut metrics = RunMetrics {
        worker_busy: vec![Duration::ZERO; n_workers],
        kernel: crate::runtime::exec_kernel_label(cfg),
        kernel_fallback: crate::runtime::kernel_fallback_note(cfg),
        pair_kernel: cfg.pair_kernel.name().to_string(),
        stream_reduce: cfg.stream_reduce,
        transport: if remote.is_some() { "tcp" } else { "sim" }.to_string(),
        pipeline_window: if remote.is_some() { cfg.pipeline_window.max(1) as u32 } else { 1 },
        shard_local_bytes,
        sharded,
        ..Default::default()
    };
    if cfg.pair_kernel == PairKernelChoice::BipartiteMerge {
        // Leader-resolved panel path; remote workers report theirs over
        // the wire and override this during the gather loop.
        metrics.panel_isa = panel_settings.isa.label().to_string();
        metrics.panel_lanes = panel_settings.isa.lanes() as u32;
        metrics.panel_fallback = simd::panel_fallback_note(cfg.panel_simd);
    }

    // Phase 1 (bipartite-merge only): every partition's local MST, once,
    // through the same worker pool — at its anchor when affinity is on, so
    // the anchor already holds the subset when the pair phase starts.
    let bip: Option<(Option<BipartiteCtx>, LocalMstCache)> = match cfg.pair_kernel {
        PairKernelChoice::Dense => None,
        PairKernelChoice::BipartiteMerge => {
            let t = Instant::now();
            let ctx = ds.map(|ds| {
                BipartiteCtx::with_settings(
                    ds,
                    cfg.metric,
                    panel_settings,
                    crate::runtime::xla_panel_dir(cfg),
                )
            });
            let (cache, phase_busy) = build_cache_pooled(
                ds,
                d,
                ctx.as_ref(),
                &plan,
                n_workers,
                cfg,
                net,
                affinity.as_ref(),
                holders.as_deref(),
                &residents,
                remote,
                &fleet,
                &leader_ingest,
            )?;
            for (w, b) in phase_busy.into_iter().enumerate() {
                metrics.worker_busy[w] += b;
            }
            metrics.phase_local_mst = t.elapsed();
            Some((ctx, cache))
        }
    };

    // Phase 2: pair jobs over the pool — per-worker affinity decks with
    // idle stealing (capability-confined claims on sharded runs), or the
    // shared LPT deal when affinity is off.
    let t_pairs = Instant::now();
    let queue = match (&affinity, caps) {
        (Some(aff), Some(caps)) => JobQueue::with_decks_capped(aff.decks.clone(), caps),
        (Some(aff), None) => JobQueue::with_decks(aff.decks.clone()),
        (None, _) => JobQueue::new(plan.lpt_order.clone()),
    };
    let (tx_leader, rx_leader) = channel::<Message>();
    let mut union_edges: Vec<Edge> = Vec::new();
    let mut worker_trees: Vec<Vec<Edge>> = Vec::new();
    let mut stream = if cfg.stream_reduce { Some(StreamReducer::new(n)) } else { None };
    let mut reduce_time = Duration::ZERO;
    let worker_errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| -> anyhow::Result<()> {
        let plan_ref = &plan;
        let queue_ref = &queue;
        let bip_ref = bip.as_ref();
        let saved_ref = &scatter_saved;
        let ingest_ref = &leader_ingest;
        let errors_ref = &worker_errors;
        let fleet_ref = &fleet;
        let use_affinity = affinity.is_some();
        for (w, resident) in residents.iter().enumerate() {
            let tx = tx_leader.clone();
            match remote {
                Some(tcp) => {
                    let cache = bip_ref.map(|(_, c)| c);
                    scope.spawn(move || {
                        pooled_worker_remote(
                            w,
                            ds,
                            d,
                            plan_ref,
                            queue_ref,
                            cfg,
                            net,
                            tcp,
                            cache,
                            use_affinity,
                            resident,
                            saved_ref,
                            ingest_ref,
                            fleet_ref,
                            errors_ref,
                            tx,
                        )
                    });
                }
                None => {
                    let ds = ds.expect("in-process execution holds the dataset");
                    scope.spawn(move || {
                        pooled_worker_local(
                            w,
                            ds,
                            plan_ref,
                            queue_ref,
                            cfg,
                            net,
                            bip_ref,
                            use_affinity,
                            resident,
                            saved_ref,
                            ingest_ref,
                            errors_ref,
                            tx,
                        )
                    });
                }
            }
        }
        drop(tx_leader); // leader keeps only rx

        let mut done = 0usize;
        while done < n_workers {
            let msg = rx_leader.recv().expect("all workers hung up");
            match msg {
                Message::Result { edges, compute, .. } => {
                    metrics.jobs += 1;
                    metrics.job_times.push(compute);
                    metrics.union_edges += edges.len();
                    if let Some(r) = &mut stream {
                        let t = Instant::now();
                        r.push(&edges);
                        reduce_time += t.elapsed();
                    } else {
                        union_edges.extend_from_slice(&edges);
                    }
                }
                Message::WorkerDone {
                    worker,
                    local_tree,
                    dist_evals,
                    busy,
                    jobs_run,
                    jobs_stolen,
                    panel_hits,
                    panel_misses,
                    panel_flops,
                    panel_time,
                    panel_threads,
                    panel_isa,
                } => {
                    metrics.dist_evals += dist_evals;
                    // += : the local-MST phase already deposited its share
                    metrics.worker_busy[worker] += busy;
                    metrics.jobs_stolen += jobs_stolen;
                    metrics.panel_hits += panel_hits;
                    metrics.panel_misses += panel_misses;
                    metrics.panel_flops += panel_flops;
                    metrics.panel_time += panel_time;
                    metrics.panel_threads_used = metrics.panel_threads_used.max(panel_threads);
                    if let Some(isa) = Isa::from_wire_code(panel_isa) {
                        // a worker that actually ran panels knows its own
                        // ISA better than the leader's local detection
                        metrics.panel_isa = isa.label().to_string();
                        metrics.panel_lanes = isa.lanes() as u32;
                    }
                    if cfg.reduce_tree {
                        metrics.jobs += jobs_run;
                    }
                    if let Some(t) = local_tree {
                        metrics.union_edges += t.len();
                        if let Some(r) = &mut stream {
                            let t0 = Instant::now();
                            r.push(&t);
                            reduce_time += t0.elapsed();
                        } else {
                            worker_trees.push(t);
                        }
                    }
                    done += 1;
                }
                other => anyhow::bail!("leader received unexpected message {other:?}"),
            }
        }
        Ok(())
    })?;

    let worker_errors = worker_errors.into_inner().unwrap();
    if !worker_errors.is_empty() {
        anyhow::bail!("distributed run failed: {}", worker_errors.join("; "));
    }
    metrics.worker_failures = fleet.failures.load(Ordering::Relaxed);
    metrics.jobs_reassigned = fleet.reassigned.load(Ordering::Relaxed);
    let expected_jobs = plan.n_jobs() as u32;
    if metrics.jobs != expected_jobs {
        anyhow::bail!(
            "job count mismatch: expected {expected_jobs}, completed {} ({} worker link(s) failed; the surviving fleet could not finish the deck)",
            metrics.jobs,
            metrics.worker_failures
        );
    }
    // Streaming folds ran inside the gather loop; carve them out of the
    // pair phase so the three phases stay (approximately) additive.
    metrics.phase_pair = t_pairs.elapsed().saturating_sub(reduce_time);

    // Final reduction. (Perf note inherited from the pre-exec leader:
    // deduplicating (u,v) pairs before the batch Kruskal was tried and
    // reverted — dedup itself sorts the full union, so it only adds work.)
    let t_mst = Instant::now();
    let mst = if let Some(r) = stream {
        metrics.reduce_folds = r.merges as u32;
        metrics.reduce_fold_edges = r.fold_edges;
        r.finish()
    } else if cfg.reduce_tree {
        // reduction runs at the leader; NetSim already charged each worker
        // tree's gather, so the final hop must not be counted again
        let (tree, _stats) = reduce_trees_with(n, &worker_trees, false);
        tree
    } else {
        kruskal(n, &union_edges)
    };
    metrics.final_mst = t_mst.elapsed();
    metrics.phase_reduce = reduce_time + metrics.final_mst;
    metrics.scatter_saved_bytes = scatter_saved.load(Ordering::Relaxed);
    metrics.leader_ingest_bytes = leader_ingest.load(Ordering::Relaxed);

    metrics.pair_evals = metrics.dist_evals;
    if let Some((_, cache)) = &bip {
        metrics.local_mst_evals = cache.evals;
        metrics.dist_evals += cache.evals;
    }

    let (s, g, c, m) = counters.snapshot();
    metrics.scatter_bytes = s;
    metrics.gather_bytes = g;
    metrics.control_bytes = c;
    metrics.messages = m;
    metrics.wall = t_start.elapsed();

    Ok(PooledRun { mst, metrics, workers: n_workers })
}

/// One in-process pooled worker: claim jobs until the decks drain (own
/// deck first, then stealing), charging the scatter for each claimed job —
/// under the resident-set model only the payload this worker does not yet
/// hold — and shipping each pair tree (or a locally ⊕-combined tree) back
/// to the leader. In-process solvers share the leader's memory; the charge
/// is the byte *model* of what the wire encoding would occupy.
fn pooled_worker_local(
    worker_id: usize,
    ds: &Dataset,
    plan: &ExecPlan,
    queue: &JobQueue,
    cfg: &RunConfig,
    net: &dyn Transport,
    bip: Option<&(Option<BipartiteCtx>, LocalMstCache)>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    scatter_saved: &AtomicU64,
    leader_ingest: &AtomicU64,
    errors: &Mutex<Vec<String>>,
    tx_leader: Sender<Message>,
) {
    let cache = bip.map(|(_, c)| c);
    let mut solver: Box<dyn PairSolver + '_> = match bip {
        Some((ctx, cache)) => {
            let ctx = ctx.as_ref().expect("in-process bipartite runs carry their context");
            Box::new(BipartitePairSolver::new(ds, ctx, cache))
        }
        None => match crate::coordinator::worker::build_kernel(cfg) {
            Ok(kernel) => Box::new(DensePairSolver::owned(ds, kernel)),
            Err(e) => {
                // Report failure as an empty done message; the leader
                // surfaces the recorded error after the gather loop.
                errors
                    .lock()
                    .unwrap()
                    .push(format!("worker {worker_id}: kernel init failed: {e:#}"));
                let _ = net.send(
                    &tx_leader,
                    Message::WorkerDone {
                        worker: worker_id,
                        local_tree: None,
                        dist_evals: 0,
                        busy: Duration::ZERO,
                        jobs_run: 0,
                        jobs_stolen: 0,
                        panel_hits: 0,
                        panel_misses: 0,
                        panel_flops: 0,
                        panel_time: Duration::ZERO,
                        panel_threads: 0,
                        panel_isa: 0,
                    },
                    Direction::Gather,
                );
                return;
            }
        },
    };
    let local_reduce = cfg.reduce_tree;
    let mut busy = Duration::ZERO;
    let mut jobs_run = 0u32;
    let mut jobs_stolen = 0u32;
    let mut local_tree: Option<Vec<Edge>> = None;
    while let Some((job_idx, stolen)) = queue.pop_for(worker_id) {
        let job = &plan.jobs[job_idx];
        let ship = charge_job_scatter(
            plan,
            job,
            ds.d,
            cache,
            use_affinity,
            resident,
            net,
            scatter_saved,
            leader_ingest,
        );
        if stolen {
            jobs_stolen += 1;
        }
        let t = Instant::now();
        let solved = match solver.solve_shipped(plan, job, &ship) {
            Ok(s) => s,
            Err(e) => {
                errors
                    .lock()
                    .unwrap()
                    .push(format!("worker {worker_id}: pair job {} failed: {e:#}", job.id));
                break;
            }
        };
        let compute = solved.compute.unwrap_or_else(|| t.elapsed());
        busy += compute;
        jobs_run += 1;
        if local_reduce {
            let t2 = Instant::now();
            local_tree = Some(match local_tree.take() {
                None => solved.edges,
                Some(prev) => tree_merge(ds.n, &prev, &solved.edges),
            });
            busy += t2.elapsed();
        } else if net
            .send(
                &tx_leader,
                Message::Result {
                    job_id: job.id,
                    worker: worker_id,
                    edges: solved.edges,
                    compute,
                },
                Direction::Gather,
            )
            .is_err()
        {
            return; // leader gone
        }
    }
    // Queue drained (or aborted): model the shutdown control message, then
    // drain the solver and report.
    net.charge(HEADER_BYTES, Direction::Control);
    let fin = match solver.finish() {
        Ok(f) => f,
        Err(e) => {
            errors
                .lock()
                .unwrap()
                .push(format!("worker {worker_id}: shutdown rendezvous failed: {e:#}"));
            SolverFinal::default()
        }
    };
    let _ = net.send(
        &tx_leader,
        Message::WorkerDone {
            worker: worker_id,
            local_tree: fin.local_tree.or(local_tree),
            dist_evals: fin.dist_evals,
            busy: fin.busy.unwrap_or(busy),
            jobs_run,
            jobs_stolen,
            panel_hits: fin.panel_hits,
            panel_misses: fin.panel_misses,
            panel_flops: fin.panel_perf.flops,
            panel_time: fin.panel_perf.time,
            panel_threads: fin.panel_perf.threads,
            panel_isa: fin.panel_perf.isa,
        },
        Direction::Gather,
    );
}

/// Mutable state of one remote link's drive loop, shared with the failure
/// handler so a dead link can return exactly the jobs it lost.
struct RemoteDrive {
    /// claimed job indices whose replies have not arrived (FIFO)
    inflight: VecDeque<usize>,
    /// reduce mode: jobs folded into the worker's never-yet-gathered tree
    acked: Vec<usize>,
    /// jobs whose results were durably recorded at the leader
    delivered: u32,
    jobs_stolen: u32,
    busy: Duration,
    fin: Option<SolverFinal>,
}

/// One remote pooled worker: drive worker `w`'s link with a bounded
/// in-flight window, and on link death hand every undelivered job back to
/// the queue's return lane for the surviving fleet.
fn pooled_worker_remote(
    worker_id: usize,
    ds: Option<&Dataset>,
    d: usize,
    plan: &ExecPlan,
    queue: &JobQueue,
    cfg: &RunConfig,
    net: &dyn Transport,
    tcp: &TcpTransport,
    cache: Option<&LocalMstCache>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    scatter_saved: &AtomicU64,
    leader_ingest: &AtomicU64,
    fleet: &Fleet,
    errors: &Mutex<Vec<String>>,
    tx_leader: Sender<Message>,
) {
    let mut st = RemoteDrive {
        inflight: VecDeque::new(),
        acked: Vec::new(),
        delivered: 0,
        jobs_stolen: 0,
        busy: Duration::ZERO,
        fin: None,
    };
    let outcome = if fleet.dead[worker_id].load(Ordering::SeqCst) {
        // Link already died in phase 1: free this deck for the survivors
        // (nothing was claimed, so nothing counts as reassigned).
        queue.abandon_deck(worker_id);
        Ok(())
    } else {
        let link = RemoteLink::new(tcp, worker_id, ds, cache, cfg.reduce_tree);
        drive_remote_link(
            worker_id,
            &link,
            plan,
            queue,
            cfg,
            net,
            cache,
            d,
            use_affinity,
            resident,
            scatter_saved,
            leader_ingest,
            fleet,
            errors,
            &tx_leader,
            &mut st,
        )
    };
    let (jobs_run, fin) = match outcome {
        Ok(()) => {
            let fin = st.fin.take().unwrap_or_default();
            (st.delivered, fin)
        }
        Err(e) => {
            // Everything claimed but not durably recorded goes back: the
            // in-flight window, plus (reduce mode) every job whose result
            // lives only in the worker's never-gathered local fold. The
            // dead flag is stored LAST — a peer that observes it must
            // already be able to see the rolled-back done count and the
            // returned jobs, or it could disperse mid-failover.
            let refolded = st.acked.len();
            let mut lost: Vec<usize> = st.inflight.drain(..).collect();
            lost.append(&mut st.acked);
            fleet.done_jobs.fetch_sub(refolded, Ordering::SeqCst);
            fleet.reassigned.fetch_add(lost.len() as u32, Ordering::Relaxed);
            queue.push_returned(&lost);
            queue.abandon_deck(worker_id);
            fleet.fail_worker(worker_id);
            eprintln!(
                "leader: worker {worker_id} link failed mid-run ({e:#}); returned {} job(s) to the deck",
                lost.len()
            );
            (st.delivered - refolded as u32, SolverFinal::default())
        }
    };
    let _ = net.send(
        &tx_leader,
        Message::WorkerDone {
            worker: worker_id,
            local_tree: fin.local_tree,
            dist_evals: fin.dist_evals,
            busy: fin.busy.unwrap_or(st.busy),
            jobs_run,
            jobs_stolen: st.jobs_stolen,
            panel_hits: fin.panel_hits,
            panel_misses: fin.panel_misses,
            panel_flops: fin.panel_perf.flops,
            panel_time: fin.panel_perf.time,
            panel_threads: fin.panel_perf.threads,
            panel_isa: fin.panel_perf.isa,
        },
        Direction::Gather,
    );
}

/// The windowed drive loop of one healthy link. Returns `Err` on a link
/// failure (socket death, protocol violation) — the caller turns the
/// undelivered state into return-lane entries.
fn drive_remote_link(
    worker_id: usize,
    link: &RemoteLink<'_>,
    plan: &ExecPlan,
    queue: &JobQueue,
    cfg: &RunConfig,
    net: &dyn Transport,
    cache: Option<&LocalMstCache>,
    d: usize,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    scatter_saved: &AtomicU64,
    leader_ingest: &AtomicU64,
    fleet: &Fleet,
    errors: &Mutex<Vec<String>>,
    tx_leader: &Sender<Message>,
    st: &mut RemoteDrive,
) -> anyhow::Result<()> {
    let window = cfg.pipeline_window.max(1);
    loop {
        // Top up the in-flight window: send the next claimed job before
        // awaiting the previous reply — scatter overlaps remote compute.
        while st.inflight.len() < window {
            let Some((job_idx, stolen)) = queue.pop_for(worker_id) else { break };
            let job = &plan.jobs[job_idx];
            let planned = plan_job_scatter(plan, job, d, cache, use_affinity, resident);
            if stolen {
                st.jobs_stolen += 1;
            }
            // Push before sending: a failed send means the job is lost in
            // flight and must be returned.
            st.inflight.push_back(job_idx);
            link.send_pair(plan, job, &planned.ship)?;
            // Counters only after the frame left: a failed send returns
            // the job unaccounted, and the survivor's re-send is the one
            // (and only) transfer the witnesses record.
            account_job_scatter(&planned, net, scatter_saved, leader_ingest);
        }
        if st.inflight.is_empty() {
            if fleet.complete() || fleet.aborted() {
                // Reduce mode: an acked fold is durable only once its
                // worker's shutdown rendezvous gathers the folded tree, so
                // shut links down in worker-id order — if a lower-id
                // peer's rendezvous fails, its acked jobs return to the
                // lane, `complete()` flips back off, and this still-open
                // link picks them up instead of having already dispersed.
                // (Only a failure at the very last live rendezvous has no
                // fleet left to recover on; that aborts loudly with a job
                // count mismatch — exactness is never at risk.)
                if cfg.reduce_tree && !fleet.aborted() {
                    let lower_all_settled = (0..worker_id).all(|v| {
                        fleet.dead[v].load(Ordering::SeqCst)
                            || fleet.finished[v].load(Ordering::SeqCst)
                    });
                    if !lower_all_settled {
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    }
                    // Settled observed — now re-read completion. A failing
                    // peer rolls its done count back *before* raising its
                    // dead flag, so a completion glimpsed before that
                    // rollback is caught here and the loop resumes
                    // claiming the returned jobs instead of dispersing.
                    if !fleet.complete() {
                        continue;
                    }
                }
                break;
            }
            // Idle but the run is not done: a peer may yet fail and return
            // jobs this worker can run. Fail fast if returned work can no
            // longer run anywhere.
            if let Some(job_idx) = queue.stranded_job(&fleet.alive()) {
                errors.lock().unwrap().push(format!(
                    "pair job {} lost: every worker capable of running it has failed",
                    plan.jobs[job_idx].id
                ));
                fleet.abort.store(true, Ordering::SeqCst);
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        // Await the oldest in-flight reply (frames are FIFO per link).
        let front_idx = *st.inflight.front().expect("checked non-empty");
        let job = &plan.jobs[front_idx];
        let solved = link.recv_pair_reply(job)?;
        st.inflight.pop_front();
        st.delivered += 1;
        fleet.done_jobs.fetch_add(1, Ordering::SeqCst);
        if cfg.reduce_tree {
            // folded remotely; only durable once the final tree is gathered
            st.acked.push(front_idx);
        } else {
            let compute = solved.compute.unwrap_or_default();
            st.busy += compute;
            if tx_leader
                .send(Message::Result {
                    job_id: job.id,
                    worker: worker_id,
                    edges: solved.edges,
                    compute,
                })
                .is_err()
            {
                anyhow::bail!("leader gather channel closed");
            }
        }
    }
    // Drained (or aborted with an empty window): shutdown rendezvous —
    // collects the worker's stats and, in reduce mode, its ⊕-folded tree.
    st.fin = Some(link.finish()?);
    // Folds gathered: peers waiting on the ordered shutdown may proceed.
    fleet.finished[worker_id].store(true, Ordering::SeqCst);
    Ok(())
}

/// One planned pair-job scatter: the shipment decision plus the byte
/// quantities its accounting needs. Splitting *planning* (claim time, under
/// the residency lock) from *accounting* (after the frame actually leaves)
/// keeps the witness counters honest on elastic runs: a job whose send
/// fails is returned and re-planned by a survivor, and only payload that
/// really traveled is ever counted.
struct PlannedScatter {
    ship: Shipment,
    bytes: u64,
    dense_bytes: u64,
    vector_bytes: u64,
}

/// Decide one claimed job's shipment under the configured byte model and
/// mark the claimed sections held (no counters touched yet).
fn plan_job_scatter(
    plan: &ExecPlan,
    job: &PairJob,
    d: usize,
    cache: Option<&LocalMstCache>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
) -> PlannedScatter {
    let full = dense_shipment(job, cache.is_some());
    let dense_bytes = shipment_bytes(plan, job, d, cache, &full);
    let (bytes, ship) = if use_affinity {
        let mut res = resident.lock().unwrap();
        let ship = residual_shipment(job, cache.is_some(), res.as_mut_slice());
        (shipment_bytes(plan, job, d, cache, &ship), ship)
    } else {
        (dense_bytes, full)
    };
    let vector_bytes = ship_vector_bytes(plan, job, d, &ship);
    PlannedScatter { ship, bytes, dense_bytes, vector_bytes }
}

/// Account one planned scatter that actually traveled (or, in-process, is
/// modeled as traveling): the transport charge, the bytes the resident-set
/// model avoided vs the dense ship-everything model, and the
/// vector-section bytes that passed through the leader
/// (`leader_ingest_bytes` — zero on sharded runs by construction).
fn account_job_scatter(
    planned: &PlannedScatter,
    net: &dyn Transport,
    scatter_saved: &AtomicU64,
    leader_ingest: &AtomicU64,
) {
    net.charge(planned.bytes, Direction::Scatter);
    scatter_saved.fetch_add(planned.dense_bytes - planned.bytes, Ordering::Relaxed);
    leader_ingest.fetch_add(planned.vector_bytes, Ordering::Relaxed);
}

/// Plan + account in one step — the in-process path, where the "transfer"
/// is the model itself and cannot fail.
fn charge_job_scatter(
    plan: &ExecPlan,
    job: &PairJob,
    d: usize,
    cache: Option<&LocalMstCache>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    net: &dyn Transport,
    scatter_saved: &AtomicU64,
    leader_ingest: &AtomicU64,
) -> Shipment {
    let planned = plan_job_scatter(plan, job, d, cache, use_affinity, resident);
    account_job_scatter(&planned, net, scatter_saved, leader_ingest);
    planned.ship
}

/// The dense-model shipment: everything the job consumes travels, every
/// time. The degenerate self-pair job (`|P| = 1`) under the bipartite
/// kernel consumes only the cached tree (its vectors were already shipped
/// by the local-MST phase); under the dense kernel it consumes the
/// subset's vectors.
pub(crate) fn dense_shipment(job: &PairJob, has_cache: bool) -> Shipment {
    if job.i == job.j {
        if has_cache {
            Shipment { tree_i: true, ..Default::default() }
        } else {
            Shipment { vec_i: true, ..Default::default() }
        }
    } else {
        Shipment { vec_i: true, vec_j: true, tree_i: has_cache, tree_j: has_cache }
    }
}

/// The resident-set shipment: the same per-subset payload as
/// [`dense_shipment`], restricted to the sections the executing worker
/// does not already hold, which are marked held afterwards. Per job this
/// is ≤ the dense model by construction (the per-section terms are
/// identical), so total affinity scatter can never exceed the dense model.
/// On leader-resident runs vectors and trees toggle together, reproducing
/// the historical one-flag model; on sharded runs vectors are pre-held
/// everywhere they were advertised and only cached trees ever ship.
fn residual_shipment(job: &PairJob, has_cache: bool, held: &mut [Held]) -> Shipment {
    let (i, j) = (job.i as usize, job.j as usize);
    let mut ship = Shipment::default();
    if i == j {
        if has_cache {
            if !held[i].tree {
                held[i].tree = true;
                ship.tree_i = true;
            }
        } else if !held[i].vecs {
            held[i].vecs = true;
            ship.vec_i = true;
        }
        return ship;
    }
    if !held[i].vecs {
        held[i].vecs = true;
        ship.vec_i = true;
    }
    if has_cache && !held[i].tree {
        held[i].tree = true;
        ship.tree_i = true;
    }
    if !held[j].vecs {
        held[j].vecs = true;
        ship.vec_j = true;
    }
    if has_cache && !held[j].tree {
        held[j].tree = true;
        ship.tree_j = true;
    }
    ship
}

/// Wire bytes of one pair-job scatter under `ship`: exactly the length of
/// the `PairAssign` frame the remote link encodes for it (header + the
/// shipped sections) — the arithmetic delegates to [`crate::net::wire`], so
/// the modeled charge and the measured frame cannot drift.
fn shipment_bytes(
    plan: &ExecPlan,
    job: &PairJob,
    d: usize,
    cache: Option<&LocalMstCache>,
    ship: &Shipment,
) -> u64 {
    let tree_bytes = |k: usize| {
        cache.map_or(0, |c| c.trees[k].len() as u64 * Edge::WIRE_BYTES as u64)
    };
    let mut bytes = HEADER_BYTES;
    if ship.vec_i {
        bytes += subset_payload_bytes(plan, job.i as usize, d);
    }
    if ship.tree_i {
        bytes += tree_bytes(job.i as usize);
    }
    if ship.vec_j {
        bytes += subset_payload_bytes(plan, job.j as usize, d);
    }
    if ship.tree_j {
        bytes += tree_bytes(job.j as usize);
    }
    bytes
}

/// Vector-section bytes of one shipment — the part of the scatter that is
/// leader-held vector payload (zero whenever only cached trees travel).
fn ship_vector_bytes(plan: &ExecPlan, job: &PairJob, d: usize, ship: &Shipment) -> u64 {
    let mut bytes = 0;
    if ship.vec_i {
        bytes += subset_payload_bytes(plan, job.i as usize, d);
    }
    if ship.vec_j {
        bytes += subset_payload_bytes(plan, job.j as usize, d);
    }
    bytes
}

/// One subset's share of a pair-job scatter: global-id map + vectors.
/// `job_wire_bytes(|S_i| + |S_j|, d) = HEADER_BYTES + Σ` of these, which is
/// what keeps the dense and resident-set models consistent per subset.
fn subset_payload_bytes(plan: &ExecPlan, k: usize, d: usize) -> u64 {
    crate::net::wire::vectors_payload_bytes(plan.parts[k].len(), d)
}

/// Build the local-MST cache through the worker pool: one job per
/// partition, heaviest first — at its anchor worker when affinity is on
/// (idle stealing as fallback), in which case the builder marks the subset
/// resident so the pair phase's byte model does not re-ship it. Scatter
/// charges each subset's vectors exactly once either way; gather charges
/// each returned local tree once. Under a remote transport, pool thread `w`
/// ships the subset as a `LocalJob` frame to remote worker `w` — which
/// keeps it resident and computes the tree over the gathered rows
/// (bit-identical, see [`crate::exec::pair_kernel::subset_mst_gathered`]).
/// On *sharded* runs the vectors are already worker-resident, so the frame
/// degenerates to a header-only `LocalAssign` and the capability mask
/// confines each subset to its holders. A worker whose link dies here is
/// marked dead, its subsets return to the lane, and the surviving holders
/// rebuild them. Also returns each pool worker's busy time so the engine
/// can attribute this phase's compute to `RunMetrics::worker_busy` (remote
/// compute is the worker-measured time from the `LocalDone` frame, not the
/// round-trip).
fn build_cache_pooled(
    ds: Option<&Dataset>,
    d: usize,
    ctx: Option<&BipartiteCtx>,
    plan: &ExecPlan,
    n_workers: usize,
    cfg: &RunConfig,
    net: &dyn Transport,
    affinity: Option<&AffinityPlan>,
    holders: Option<&[Vec<bool>]>,
    residents: &[Mutex<Vec<Held>>],
    remote: Option<&TcpTransport>,
    fleet: &Fleet,
    leader_ingest: &AtomicU64,
) -> anyhow::Result<(LocalMstCache, Vec<Duration>)> {
    let t = Instant::now();
    let p = plan.parts.len();
    let queue = match (affinity, holders) {
        (Some(aff), Some(h)) => {
            JobQueue::with_decks_capped(aff.local_decks.clone(), h.to_vec())
        }
        (Some(aff), None) => JobQueue::with_decks(aff.local_decks.clone()),
        (None, _) => {
            let mut order: Vec<usize> = (0..p).collect();
            order.sort_by(|&a, &b| plan.parts[b].len().cmp(&plan.parts[a].len()).then(a.cmp(&b)));
            JobQueue::new(order)
        }
    };
    let counter = CountingMetric::new(cfg.metric);
    let slots: Vec<Mutex<Option<Vec<Edge>>>> = (0..p).map(|_| Mutex::new(None)).collect();
    let built = AtomicUsize::new(0);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let n_spawn = if remote.is_some() { n_workers } else { n_workers.min(p) };
    let busy: Vec<Mutex<Duration>> = (0..n_spawn).map(|_| Mutex::new(Duration::ZERO)).collect();
    std::thread::scope(|scope| {
        let queue_ref = &queue;
        let counter_ref = &counter;
        let slots_ref = &slots;
        let built_ref = &built;
        let errors_ref = &errors;
        for (w, busy_slot) in busy.iter().enumerate() {
            let resident = &residents[w];
            scope.spawn(move || loop {
                let claimed = queue_ref.pop_for(w);
                let Some((k, _stolen)) = claimed else {
                    match remote {
                        None => return, // in-process: a drained queue is final
                        Some(_) => {
                            if built_ref.load(Ordering::SeqCst) >= p || fleet.aborted() {
                                return;
                            }
                            if let Some(k) = queue_ref.stranded_job(&fleet.alive()) {
                                errors_ref.lock().unwrap().push(format!(
                                    "subset {k}: every worker holding it has failed"
                                ));
                                fleet.abort.store(true, Ordering::SeqCst);
                                return;
                            }
                            std::thread::sleep(Duration::from_millis(1));
                            continue;
                        }
                    }
                };
                let ids = &plan.parts[k];
                let sharded = ds.is_none();
                let tree = if let Some(tcp) = remote {
                    let msg = if sharded {
                        Message::LocalAssign { part: k as u32 }
                    } else {
                        Message::LocalJob {
                            part: k as u32,
                            global_ids: ids.clone(),
                            points: ds.expect("unsharded remote holds the dataset").gather(ids),
                        }
                    };
                    // Ingest accounted only after the frame actually left:
                    // a failed send returns the subset to the lane and the
                    // survivor's re-send is the transfer that counts.
                    let reply = tcp.send_to(w, &msg, Direction::Scatter).and_then(|_| {
                        if let Some(ds) = ds {
                            leader_ingest.fetch_add(
                                crate::net::wire::vectors_payload_bytes(ids.len(), ds.d),
                                Ordering::Relaxed,
                            );
                        }
                        tcp.recv_from(w)
                    });
                    match reply {
                        Ok(Message::LocalDone { part, edges, compute })
                            if part as usize == k =>
                        {
                            *busy_slot.lock().unwrap() += compute;
                            edges
                        }
                        Ok(other) => {
                            // recovery state first, dead flag last (see
                            // Fleet::fail_worker)
                            queue_ref.push_returned(&[k]);
                            queue_ref.abandon_deck(w);
                            fleet.reassigned.fetch_add(1, Ordering::Relaxed);
                            fleet.fail_worker(w);
                            eprintln!(
                                "leader: worker {w} answered subset {k} with {other:?}; treating the link as failed"
                            );
                            return;
                        }
                        Err(e) => {
                            queue_ref.push_returned(&[k]);
                            queue_ref.abandon_deck(w);
                            fleet.reassigned.fetch_add(1, Ordering::Relaxed);
                            fleet.fail_worker(w);
                            eprintln!(
                                "leader: worker {w} link failed on subset {k} ({e:#}); returned it to the deck"
                            );
                            return;
                        }
                    }
                } else {
                    let ds = ds.expect("in-process phase 1 holds the dataset");
                    let ctx = ctx.expect("in-process phase 1 carries the bipartite context");
                    // the modeled scatter of this subset's vectors (the
                    // in-process "transfer" is the model and cannot fail)
                    net.charge(job_wire_bytes(ids.len(), ds.d), Direction::Scatter);
                    leader_ingest.fetch_add(
                        crate::net::wire::vectors_payload_bytes(ids.len(), ds.d),
                        Ordering::Relaxed,
                    );
                    let t_job = Instant::now();
                    let tree = subset_mst(
                        ds.as_slice(),
                        ds.d,
                        ctx.block.as_ref(),
                        &ctx.aux,
                        counter_ref,
                        ids,
                    );
                    *busy_slot.lock().unwrap() += t_job.elapsed();
                    tree
                };
                net.charge(
                    HEADER_BYTES + tree.len() as u64 * Edge::WIRE_BYTES as u64,
                    Direction::Gather,
                );
                {
                    // the claiming worker now holds the subset's vectors
                    // (already true on sharded runs) and its cached tree
                    let mut res = resident.lock().unwrap();
                    res[k].vecs = true;
                    res[k].tree = true;
                }
                *slots_ref[k].lock().unwrap() = Some(tree);
                built_ref.fetch_add(1, Ordering::SeqCst);
            });
        }
    });
    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        anyhow::bail!("local-MST phase failed: {}", errors.join("; "));
    }
    let trees: Vec<Vec<Edge>> = slots
        .into_iter()
        .enumerate()
        .map(|(k, s)| {
            s.into_inner()
                .unwrap()
                .ok_or_else(|| anyhow::anyhow!("subset {k} local MST missing (worker failure?)"))
        })
        .collect::<anyhow::Result<_>>()?;
    // The remote workers did the cache evaluations; the count is exact from
    // the partition shape (Prim over m points is always C(m, 2) pairs) —
    // identical to what the in-process CountingMetric records.
    let evals = if remote.is_some() {
        plan.parts
            .iter()
            .map(|p| {
                let m = p.len() as u64;
                m * m.saturating_sub(1) / 2
            })
            .sum()
    } else {
        counter.evals()
    };
    let busy: Vec<Duration> = busy.into_iter().map(|b| b.into_inner().unwrap()).collect();
    Ok((LocalMstCache { trees, evals, build_time: t.elapsed() }, busy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KernelChoice;
    use crate::data::generators::uniform;
    use crate::decomp::decomposed_mst;
    use crate::dense::PrimDense;
    use crate::geometry::MetricKind;
    use crate::mst::normalize_tree;
    use crate::net::NetSim;
    use crate::util::prng::Pcg64;

    fn int_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(25) as f32 - 12.0).collect();
        Dataset::new(n, d, data)
    }

    #[test]
    fn bipartite_serial_matches_dense_serial() {
        let ds = int_dataset(500, 70, 6);
        for parts in [1usize, 2, 3, 5, 7] {
            let cfg = DecompConfig { parts, ..Default::default() };
            let dense = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
            let bip = decomposed_mst_bipartite(&ds, &cfg, MetricKind::SqEuclid);
            assert_eq!(
                normalize_tree(&dense.mst),
                normalize_tree(&bip.mst),
                "parts={parts}"
            );
            let n = ds.n as u64;
            assert_eq!(bip.dist_evals, n * (n - 1) / 2, "parts={parts}: exactly C(n,2)");
            if parts >= 3 {
                assert!(
                    bip.dist_evals < dense.dist_evals,
                    "parts={parts}: bipartite {} !< dense {}",
                    bip.dist_evals,
                    dense.dist_evals
                );
            }
        }
    }

    #[test]
    fn pooled_bipartite_matches_pooled_dense_all_worker_counts() {
        let ds = int_dataset(501, 80, 5);
        let mut cfg = RunConfig {
            parts: 4,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let dense = execute_pooled(&ds, &cfg, &net).unwrap();
        cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
        for workers in [1usize, 3, 6] {
            cfg.workers = workers;
            let net = NetSim::new(cfg.net.clone());
            let bip = execute_pooled(&ds, &cfg, &net).unwrap();
            assert_eq!(
                normalize_tree(&dense.mst),
                normalize_tree(&bip.mst),
                "workers={workers}"
            );
            let n = ds.n as u64;
            assert_eq!(bip.metrics.dist_evals, n * (n - 1) / 2, "workers={workers}");
            assert_eq!(
                bip.metrics.local_mst_evals + bip.metrics.pair_evals,
                bip.metrics.dist_evals
            );
            assert!(bip.metrics.local_mst_evals > 0);
        }
    }

    #[test]
    fn stream_reduce_matches_batch() {
        let ds = int_dataset(502, 60, 4);
        let mut cfg = RunConfig {
            parts: 5,
            workers: 3,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let batch = execute_pooled(&ds, &cfg, &net).unwrap();
        cfg.stream_reduce = true;
        let net = NetSim::new(cfg.net.clone());
        let streamed = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(normalize_tree(&batch.mst), normalize_tree(&streamed.mst));
        assert_eq!(batch.metrics.union_edges, streamed.metrics.union_edges);
        assert!(streamed.metrics.stream_reduce);
        // streaming also composes with worker-local ⊕-reduction
        cfg.reduce_tree = true;
        let net = NetSim::new(cfg.net.clone());
        let both = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(normalize_tree(&batch.mst), normalize_tree(&both.mst));
    }

    #[test]
    fn lpt_scatter_bytes_match_dense_model() {
        // With affinity routing off, the pull-based scheduler must charge
        // the identical per-job scatter the eager round-robin leader
        // charged — the dense model stays available byte-for-byte.
        let ds = uniform(96, 7, 1.0, Pcg64::seeded(503));
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            strategy: crate::decomp::PartitionStrategy::Block,
            affinity: false,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        let m = 2 * 96 / 4;
        let per_job = 16 + m as u64 * 4 + (m * 7) as u64 * 4;
        assert_eq!(out.metrics.scatter_bytes, 6 * per_job);
        assert_eq!(out.metrics.scatter_saved_bytes, 0, "dense model saves nothing");
        assert_eq!(out.metrics.jobs_stolen, 0, "single shared deck: nothing counts as stolen");
        // every scattered byte above is leader-held vector payload + header
        assert_eq!(out.metrics.leader_ingest_bytes, 6 * (per_job - 16));
        assert_eq!(out.metrics.worker_failures, 0);
        assert_eq!(out.metrics.jobs_reassigned, 0);
        assert!(!out.metrics.sharded);
    }

    /// The resident-set invariant that makes the affinity model auditable:
    /// per job, charged + saved equals the dense model exactly, so for any
    /// seed/strategy/worker count `affinity.scatter + affinity.saved ==
    /// dense.scatter` — and the tree is unchanged.
    #[test]
    fn affinity_scatter_plus_saved_equals_dense_model() {
        let ds = int_dataset(505, 90, 6);
        for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            for workers in [1usize, 2, 4] {
                let mut cfg = RunConfig {
                    parts: 5,
                    workers,
                    kernel: KernelChoice::PrimDense,
                    pair_kernel,
                    ..Default::default()
                };
                cfg.affinity = false;
                let net = NetSim::new(cfg.net.clone());
                let dense = execute_pooled(&ds, &cfg, &net).unwrap();
                cfg.affinity = true;
                let net = NetSim::new(cfg.net.clone());
                let aff = execute_pooled(&ds, &cfg, &net).unwrap();
                assert_eq!(
                    normalize_tree(&dense.mst),
                    normalize_tree(&aff.mst),
                    "{pair_kernel:?} workers={workers}: affinity must not change the tree"
                );
                assert_eq!(
                    aff.metrics.scatter_bytes + aff.metrics.scatter_saved_bytes,
                    dense.metrics.scatter_bytes,
                    "{pair_kernel:?} workers={workers}: charged + saved == dense model"
                );
                assert!(
                    aff.metrics.scatter_bytes <= dense.metrics.scatter_bytes,
                    "{pair_kernel:?} workers={workers}: affinity can never charge more"
                );
                assert_eq!(aff.metrics.dist_evals, dense.metrics.dist_evals);
            }
        }
    }

    /// parts ≥ 4 with few workers: by pigeonhole some worker runs more pair
    /// jobs than a maximum matching over the subsets, so at least one job
    /// must share a subset with an earlier job on that worker — the
    /// resident-set model saves strictly positive bytes, for both kernels.
    #[test]
    fn affinity_saves_strictly_for_parts_ge_4() {
        let ds = int_dataset(506, 80, 5);
        for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            for workers in [1usize, 2] {
                let cfg = RunConfig {
                    parts: 4,
                    workers,
                    kernel: KernelChoice::PrimDense,
                    pair_kernel,
                    ..Default::default()
                };
                let net = NetSim::new(cfg.net.clone());
                let out = execute_pooled(&ds, &cfg, &net).unwrap();
                assert!(
                    out.metrics.scatter_saved_bytes > 0,
                    "{pair_kernel:?} workers={workers}: expected strict scatter savings"
                );
            }
        }
    }

    #[test]
    fn bipartite_panel_cache_metrics_populated() {
        let ds = int_dataset(507, 64, 4);
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            strategy: crate::decomp::PartitionStrategy::Block,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        // 6 cross jobs × 2 panel probes, however they land on the workers
        assert_eq!(out.metrics.panel_hits + out.metrics.panel_misses, 12);
        // 2 workers, 6 jobs: some worker ran ≥ 3 jobs over 4 subsets, which
        // cannot be pairwise disjoint — at least one probe hit
        assert!(out.metrics.panel_hits > 0, "panel cache never hit");
        assert!(out.metrics.panel_hit_rate() > 0.0);
    }

    #[test]
    fn stream_reduce_fold_metrics_populated() {
        let ds = int_dataset(508, 60, 4);
        let cfg = RunConfig {
            parts: 5,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            stream_reduce: true,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(out.metrics.reduce_folds, 10, "one fold per pair tree");
        assert!(out.metrics.reduce_fold_edges > 0);
        assert!(
            out.metrics.reduce_fold_edges <= out.metrics.reduce_folds as u64 * 2 * (ds.n as u64 - 1),
            "streaming folds stay O(|V|) each: {} edges over {} folds",
            out.metrics.reduce_fold_edges,
            out.metrics.reduce_folds
        );
    }

    #[test]
    fn bipartite_phase_metrics_populated() {
        let ds = int_dataset(504, 64, 4);
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            strategy: crate::decomp::PartitionStrategy::Block,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(out.metrics.pair_kernel, "bipartite-merge");
        assert!(out.metrics.kernel.contains("bipartite-merge"), "{}", out.metrics.kernel);
        assert!(out.metrics.phase_local_mst > Duration::ZERO);
        assert!(out.metrics.phase_pair > Duration::ZERO);
        // 4 partitions of 16: cache = 4 * C(16,2), pairs = 6 * 16 * 16
        assert_eq!(out.metrics.local_mst_evals, 4 * (16 * 15 / 2));
        assert_eq!(out.metrics.pair_evals, 6 * 16 * 16);
    }

    /// The split-flag resident model must reproduce the historical
    /// one-flag shipments on every leader-resident sequence: vectors and
    /// trees always travel (and are marked) together.
    #[test]
    fn residual_shipment_marks_vectors_and_trees_together() {
        let mut held = vec![Held::default(); 3];
        let job01 = PairJob { id: 0, i: 0, j: 1 };
        let job12 = PairJob { id: 1, i: 1, j: 2 };
        let s = residual_shipment(&job01, true, &mut held);
        assert_eq!(
            s,
            Shipment { vec_i: true, vec_j: true, tree_i: true, tree_j: true }
        );
        let s = residual_shipment(&job12, true, &mut held);
        assert_eq!(
            s,
            Shipment { vec_j: true, tree_j: true, ..Default::default() },
            "subset 1 already fully held"
        );
        // sharded seeding: vectors pre-held, only trees ship
        let mut held = vec![Held { vecs: true, tree: false }; 3];
        let s = residual_shipment(&job01, true, &mut held);
        assert_eq!(s, Shipment { tree_i: true, tree_j: true, ..Default::default() });
        let s = residual_shipment(&job01, true, &mut held);
        assert_eq!(s, Shipment::default(), "everything held on repeat");
        // dense kernel on a sharded run ships nothing at all
        let mut held = vec![Held { vecs: true, tree: false }; 3];
        assert_eq!(residual_shipment(&job01, false, &mut held), Shipment::default());
    }
}
