//! The pair-job execution engine: the single owner of
//! partition → schedule → solve → reduce for *both* front-ends.
//!
//! [`run_serial`] drives a [`PairSolver`] on the calling thread in the
//! paper's schedule order (the `decomp::decomposed_mst` reference path);
//! [`execute_pooled`] drives a `std::thread` worker pool with cost-LPT
//! dealing + idle stealing over the same plan, with every scatter/gather
//! charged to a [`Transport`] (the `coordinator::run_distributed` path —
//! the simulated [`NetSim`](crate::net::NetSim) byte model by default, or
//! real TCP links via [`execute_pooled_remote`], where each pool thread
//! proxies its jobs to a remote `demst worker` process through a
//! [`RemoteSolver`] and the counters are fed by actual frame sizes).
//! Per-phase timings and evaluation counters land in [`RunMetrics`].
//!
//! Pooled flow, bipartite-merge kernel:
//!
//! ```text
//! phase local-MST:  pool over partitions   — MST(S_k) once each, cached
//! phase pair:       pool over pair jobs    — filtered Prim per (S_i, S_j)
//! phase reduce:     leader                 — streaming ⊕ or batch Kruskal
//! ```
//!
//! The dense kernel skips the first phase and solves each pair with a full
//! d-MST over the gathered union, exactly as before the refactor.

use super::pair_kernel::{
    subset_mst, BipartiteCtx, BipartitePairSolver, DensePairSolver, LocalMstCache, PairSolver,
    Shipment, SolverFinal,
};
use super::plan::{AffinityPlan, ExecPlan};
use super::scheduler::JobQueue;
use crate::config::{PairKernelChoice, RunConfig};
use crate::coordinator::messages::{job_wire_bytes, Message, HEADER_BYTES};
use crate::coordinator::metrics::RunMetrics;
use crate::data::Dataset;
use crate::net::remote::RemoteSolver;
use crate::net::{Direction, TcpTransport, Transport};
use crate::decomp::reduction::{reduce_trees_with, tree_merge, StreamReducer};
use crate::decomp::{pair_count, DecompConfig, DecompOutput, PairJob};
use crate::geometry::CountingMetric;
use crate::graph::Edge;
use crate::mst::kruskal;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Resolve the worker count: explicit, else one per pair job capped at the
/// machine's parallelism.
pub fn resolve_workers(cfg: &RunConfig) -> usize {
    let jobs = pair_count(cfg.parts).max(1);
    if cfg.workers > 0 {
        cfg.workers.min(jobs)
    } else {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
        jobs.min(cores)
    }
}

/// Output of a serial engine run.
pub struct SerialRun {
    /// the exact global MSF
    pub mst: Vec<Edge>,
    /// total edges across all pair trees (the `O(|V|·|P|)` gather payload)
    pub union_edges: usize,
    /// per-pair trees in schedule order, if requested
    pub pair_trees: Vec<Vec<Edge>>,
    /// pair jobs executed
    pub jobs: usize,
}

/// Drive `solver` over the plan's jobs on the calling thread (schedule
/// order), then take the sparse MST of the union.
pub fn run_serial(
    n: usize,
    plan: &ExecPlan,
    solver: &mut dyn PairSolver,
    keep_pair_trees: bool,
) -> SerialRun {
    let mut union_edges: Vec<Edge> = Vec::new();
    let mut pair_trees = Vec::new();
    for job in &plan.jobs {
        let tree = solver.solve(plan, job);
        union_edges.extend_from_slice(&tree);
        if keep_pair_trees {
            pair_trees.push(tree);
        }
    }
    let union_count = union_edges.len();
    let mst = kruskal(n, &union_edges);
    SerialRun { mst, union_edges: union_count, pair_trees, jobs: plan.n_jobs() }
}

/// Serial decomposed MST with the bipartite-merge pair kernel: local MSTs
/// cached once, pair jobs solved by filtered Prim. Returns the same
/// [`DecompOutput`] as the dense reference path, with `dist_evals` =
/// `Σ_k |S_k|(|S_k|-1)/2 + Σ_{i<j} |S_i|·|S_j| = n(n-1)/2` exactly.
pub fn decomposed_mst_bipartite(
    ds: &Dataset,
    cfg: &DecompConfig,
    kind: crate::geometry::MetricKind,
) -> DecompOutput {
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    let ctx = BipartiteCtx::new(ds, kind);
    let cache = LocalMstCache::build_serial(ds, &ctx, &plan.parts);
    let mut solver = BipartitePairSolver::new(ds, &ctx, &cache);
    let run = run_serial(ds.n, &plan, &mut solver, cfg.keep_pair_trees);
    DecompOutput {
        mst: run.mst,
        union_edges: run.union_edges,
        dist_evals: cache.evals + solver.dist_evals(),
        jobs: run.jobs,
        pair_trees: run.pair_trees,
        part_sizes: plan.part_sizes(),
    }
}

/// Output of a pooled engine run.
pub struct PooledRun {
    pub mst: Vec<Edge>,
    pub metrics: RunMetrics,
    pub workers: usize,
}

/// The pooled engine: worker threads claim jobs from per-worker affinity
/// decks (cost-LPT within each deck, idle stealing as fallback; one shared
/// LPT queue when `cfg.affinity` is off); the leader gathers trees
/// (streaming or buffered) and finishes the reduction. All traffic is
/// charged to `net` — under the resident-set model only payload the
/// executing worker is missing, with the dense model's difference recorded
/// in `RunMetrics::scatter_saved_bytes`.
pub fn execute_pooled(
    ds: &Dataset,
    cfg: &RunConfig,
    net: &dyn Transport,
) -> anyhow::Result<PooledRun> {
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    execute_pooled_inner(ds, cfg, net, None, plan)
}

/// The identical pooled engine run against **remote worker processes**:
/// pool thread `w` proxies every job it claims (same decks, same resident-
/// set model, same stealing) to remote worker `w` through a
/// [`RemoteSolver`] over `tcp`'s socket. [`Transport::charge`] no-ops on
/// the TCP transport — the counters are fed by the actual encoded frames
/// the proxies and the local-MST phase put on the wire, which equal the
/// modeled charges byte-for-byte because [`Message::wire_bytes`] is
/// computed from the real wire encoding.
///
/// `plan` is the **same plan the handshake announced**: the caller
/// ([`crate::net::launch::serve`]) partitions once, tells every worker the
/// partition layout in its `Setup` frame, and hands the identical plan
/// here — so the section lengths workers derive for `PairAssign` frames
/// can never drift from the jobs the engine actually ships.
pub fn execute_pooled_remote(
    ds: &Dataset,
    cfg: &RunConfig,
    tcp: &TcpTransport,
    plan: ExecPlan,
) -> anyhow::Result<PooledRun> {
    execute_pooled_inner(ds, cfg, tcp, Some(tcp), plan)
}

fn execute_pooled_inner(
    ds: &Dataset,
    cfg: &RunConfig,
    net: &dyn Transport,
    remote: Option<&TcpTransport>,
    plan: ExecPlan,
) -> anyhow::Result<PooledRun> {
    let t_start = Instant::now();
    let n_workers = resolve_workers(cfg);
    if let Some(tcp) = remote {
        anyhow::ensure!(
            tcp.len() == n_workers,
            "transport holds {} worker links but the plan resolves to {n_workers} workers",
            tcp.len()
        );
    }
    let counters = net.counters();

    // Subset-affinity routing + resident-set byte model (cfg.affinity):
    // each subset gets an anchor worker, jobs land on the anchor of their
    // larger subset, and each worker remembers which subsets (vectors +
    // cached tree) it already holds — residency persists from the local-MST
    // phase into the pair phase, and only the *missing* payload is charged.
    let affinity: Option<AffinityPlan> = cfg.affinity.then(|| plan.affinity(n_workers));
    let residents: Vec<Mutex<Vec<bool>>> =
        (0..n_workers).map(|_| Mutex::new(vec![false; plan.parts.len()])).collect();
    let scatter_saved = AtomicU64::new(0);

    let mut metrics = RunMetrics {
        worker_busy: vec![Duration::ZERO; n_workers],
        kernel: crate::runtime::exec_kernel_label(cfg),
        kernel_fallback: crate::runtime::kernel_fallback_note(cfg),
        pair_kernel: cfg.pair_kernel.name().to_string(),
        stream_reduce: cfg.stream_reduce,
        transport: if remote.is_some() { "tcp" } else { "sim" }.to_string(),
        ..Default::default()
    };

    // Phase 1 (bipartite-merge only): every partition's local MST, once,
    // through the same worker pool — at its anchor when affinity is on, so
    // the anchor already holds the subset when the pair phase starts.
    let bip: Option<(BipartiteCtx, LocalMstCache)> = match cfg.pair_kernel {
        PairKernelChoice::Dense => None,
        PairKernelChoice::BipartiteMerge => {
            let t = Instant::now();
            let ctx = BipartiteCtx::new(ds, cfg.metric);
            let (cache, phase_busy) = build_cache_pooled(
                ds,
                &ctx,
                &plan,
                n_workers,
                net,
                affinity.as_ref(),
                &residents,
                remote,
            )?;
            for (w, b) in phase_busy.into_iter().enumerate() {
                metrics.worker_busy[w] += b;
            }
            metrics.phase_local_mst = t.elapsed();
            Some((ctx, cache))
        }
    };

    // Phase 2: pair jobs over the pool — per-worker affinity decks with
    // idle stealing, or the shared LPT deal when affinity is off.
    let t_pairs = Instant::now();
    let queue = match &affinity {
        Some(aff) => JobQueue::with_decks(aff.decks.clone()),
        None => JobQueue::new(plan.lpt_order.clone()),
    };
    let (tx_leader, rx_leader) = channel::<Message>();
    let mut union_edges: Vec<Edge> = Vec::new();
    let mut worker_trees: Vec<Vec<Edge>> = Vec::new();
    let mut stream = if cfg.stream_reduce { Some(StreamReducer::new(ds.n)) } else { None };
    let mut reduce_time = Duration::ZERO;
    let worker_errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| -> anyhow::Result<()> {
        let plan_ref = &plan;
        let queue_ref = &queue;
        let bip_ref = bip.as_ref();
        let saved_ref = &scatter_saved;
        let errors_ref = &worker_errors;
        let use_affinity = affinity.is_some();
        for (w, resident) in residents.iter().enumerate() {
            let tx = tx_leader.clone();
            scope.spawn(move || {
                pooled_worker(
                    w,
                    ds,
                    plan_ref,
                    queue_ref,
                    cfg,
                    net,
                    remote,
                    bip_ref,
                    use_affinity,
                    resident,
                    saved_ref,
                    errors_ref,
                    tx,
                )
            });
        }
        drop(tx_leader); // leader keeps only rx

        let mut done = 0usize;
        while done < n_workers {
            let msg = rx_leader.recv().expect("all workers hung up");
            match msg {
                Message::Result { edges, compute, .. } => {
                    metrics.jobs += 1;
                    metrics.job_times.push(compute);
                    metrics.union_edges += edges.len();
                    if let Some(r) = &mut stream {
                        let t = Instant::now();
                        r.push(&edges);
                        reduce_time += t.elapsed();
                    } else {
                        union_edges.extend_from_slice(&edges);
                    }
                }
                Message::WorkerDone {
                    worker,
                    local_tree,
                    dist_evals,
                    busy,
                    jobs_run,
                    jobs_stolen,
                    panel_hits,
                    panel_misses,
                } => {
                    metrics.dist_evals += dist_evals;
                    // += : the local-MST phase already deposited its share
                    metrics.worker_busy[worker] += busy;
                    metrics.jobs_stolen += jobs_stolen;
                    metrics.panel_hits += panel_hits;
                    metrics.panel_misses += panel_misses;
                    if cfg.reduce_tree {
                        metrics.jobs += jobs_run;
                    }
                    if let Some(t) = local_tree {
                        metrics.union_edges += t.len();
                        if let Some(r) = &mut stream {
                            let t0 = Instant::now();
                            r.push(&t);
                            reduce_time += t0.elapsed();
                        } else {
                            worker_trees.push(t);
                        }
                    }
                    done += 1;
                }
                other => anyhow::bail!("leader received unexpected message {other:?}"),
            }
        }
        Ok(())
    })?;

    let worker_errors = worker_errors.into_inner().unwrap();
    if !worker_errors.is_empty() {
        anyhow::bail!("distributed run failed: {}", worker_errors.join("; "));
    }
    let expected_jobs = plan.n_jobs() as u32;
    if metrics.jobs != expected_jobs {
        anyhow::bail!(
            "job count mismatch: expected {expected_jobs}, completed {} (worker failure?)",
            metrics.jobs
        );
    }
    // Streaming folds ran inside the gather loop; carve them out of the
    // pair phase so the three phases stay (approximately) additive.
    metrics.phase_pair = t_pairs.elapsed().saturating_sub(reduce_time);

    // Final reduction. (Perf note inherited from the pre-exec leader:
    // deduplicating (u,v) pairs before the batch Kruskal was tried and
    // reverted — dedup itself sorts the full union, so it only adds work.)
    let t_mst = Instant::now();
    let mst = if let Some(r) = stream {
        metrics.reduce_folds = r.merges as u32;
        metrics.reduce_fold_edges = r.fold_edges;
        r.finish()
    } else if cfg.reduce_tree {
        // reduction runs at the leader; NetSim already charged each worker
        // tree's gather, so the final hop must not be counted again
        let (tree, _stats) = reduce_trees_with(ds.n, &worker_trees, false);
        tree
    } else {
        kruskal(ds.n, &union_edges)
    };
    metrics.final_mst = t_mst.elapsed();
    metrics.phase_reduce = reduce_time + metrics.final_mst;
    metrics.scatter_saved_bytes = scatter_saved.load(Ordering::Relaxed);

    metrics.pair_evals = metrics.dist_evals;
    if let Some((_, cache)) = &bip {
        metrics.local_mst_evals = cache.evals;
        metrics.dist_evals += cache.evals;
    }

    let (s, g, c, m) = counters.snapshot();
    metrics.scatter_bytes = s;
    metrics.gather_bytes = g;
    metrics.control_bytes = c;
    metrics.messages = m;
    metrics.wall = t_start.elapsed();

    Ok(PooledRun { mst, metrics, workers: n_workers })
}

/// One pooled worker: claim jobs until the decks drain (own deck first,
/// then stealing), charging the scatter for each claimed job — under the
/// resident-set model only the payload this worker does not yet hold — and
/// shipping each pair tree (or a locally ⊕-combined tree) back to the
/// leader. In-process solvers share the leader's memory (the charge is the
/// byte *model*); under [`execute_pooled_remote`] the solver is a
/// [`RemoteSolver`] that puts exactly the computed [`Shipment`] on its
/// worker's socket, so the modeled and measured bytes agree per job.
fn pooled_worker(
    worker_id: usize,
    ds: &Dataset,
    plan: &ExecPlan,
    queue: &JobQueue,
    cfg: &RunConfig,
    net: &dyn Transport,
    remote: Option<&TcpTransport>,
    bip: Option<&(BipartiteCtx, LocalMstCache)>,
    use_affinity: bool,
    resident: &Mutex<Vec<bool>>,
    scatter_saved: &AtomicU64,
    errors: &Mutex<Vec<String>>,
    tx_leader: Sender<Message>,
) {
    let cache = bip.map(|(_, c)| c);
    let mut solver: Box<dyn PairSolver + '_> = if let Some(tcp) = remote {
        Box::new(RemoteSolver::new(tcp, worker_id, ds, cache, cfg.reduce_tree))
    } else {
        match bip {
            Some((ctx, cache)) => Box::new(BipartitePairSolver::new(ds, ctx, cache)),
            None => match crate::coordinator::worker::build_kernel(cfg) {
                Ok(kernel) => Box::new(DensePairSolver::owned(ds, kernel)),
                Err(e) => {
                    // Report failure as an empty done message; the leader
                    // surfaces the recorded error after the gather loop.
                    errors
                        .lock()
                        .unwrap()
                        .push(format!("worker {worker_id}: kernel init failed: {e:#}"));
                    let _ = net.send(
                        &tx_leader,
                        Message::WorkerDone {
                            worker: worker_id,
                            local_tree: None,
                            dist_evals: 0,
                            busy: Duration::ZERO,
                            jobs_run: 0,
                            jobs_stolen: 0,
                            panel_hits: 0,
                            panel_misses: 0,
                        },
                        Direction::Gather,
                    );
                    return;
                }
            },
        }
    };
    let local_reduce = cfg.reduce_tree;
    let mut busy = Duration::ZERO;
    let mut jobs_run = 0u32;
    let mut jobs_stolen = 0u32;
    let mut local_tree: Option<Vec<Edge>> = None;
    while let Some((job_idx, stolen)) = queue.pop_for(worker_id) {
        let job = &plan.jobs[job_idx];
        // The leader→worker scatter of this job's payload: what the dense
        // model would ship, minus what this worker already holds.
        let full = dense_shipment(job, cache.is_some());
        let dense_bytes = shipment_bytes(plan, job, ds.d, cache, &full);
        let (bytes, ship) = if use_affinity {
            let mut res = resident.lock().unwrap();
            let ship = residual_shipment(job, cache.is_some(), res.as_mut_slice());
            (shipment_bytes(plan, job, ds.d, cache, &ship), ship)
        } else {
            (dense_bytes, full)
        };
        net.charge(bytes, Direction::Scatter);
        scatter_saved.fetch_add(dense_bytes - bytes, Ordering::Relaxed);
        if stolen {
            jobs_stolen += 1;
        }
        let t = Instant::now();
        let solved = match solver.solve_shipped(plan, job, &ship) {
            Ok(s) => s,
            Err(e) => {
                errors
                    .lock()
                    .unwrap()
                    .push(format!("worker {worker_id}: pair job {} failed: {e:#}", job.id));
                break;
            }
        };
        let compute = solved.compute.unwrap_or_else(|| t.elapsed());
        busy += compute;
        jobs_run += 1;
        if local_reduce {
            // A remote solver ⊕-folds on the far side of the wire (its Ack
            // carries nothing); folding its empty returns again would be a
            // second reduction.
            if !solver.folds_remotely() {
                let t2 = Instant::now();
                local_tree = Some(match local_tree.take() {
                    None => solved.edges,
                    Some(prev) => tree_merge(ds.n, &prev, &solved.edges),
                });
                busy += t2.elapsed();
            }
        } else if net
            .send(
                &tx_leader,
                Message::Result {
                    job_id: job.id,
                    worker: worker_id,
                    edges: solved.edges,
                    compute,
                },
                Direction::Gather,
            )
            .is_err()
        {
            return; // leader gone
        }
    }
    // Queue drained (or aborted): model the shutdown control message, then
    // drain the solver — for the remote proxy this is the shutdown
    // rendezvous that collects the worker process's final stats (and its
    // remotely ⊕-folded tree in reduce mode) — and report.
    net.charge(HEADER_BYTES, Direction::Control);
    let fin = match solver.finish() {
        Ok(f) => f,
        Err(e) => {
            errors
                .lock()
                .unwrap()
                .push(format!("worker {worker_id}: shutdown rendezvous failed: {e:#}"));
            SolverFinal::default()
        }
    };
    let _ = net.send(
        &tx_leader,
        Message::WorkerDone {
            worker: worker_id,
            local_tree: fin.local_tree.or(local_tree),
            dist_evals: fin.dist_evals,
            busy: fin.busy.unwrap_or(busy),
            jobs_run,
            jobs_stolen,
            panel_hits: fin.panel_hits,
            panel_misses: fin.panel_misses,
        },
        Direction::Gather,
    );
}

/// The dense-model shipment: everything the job consumes travels, every
/// time. The degenerate self-pair job (`|P| = 1`) under the bipartite
/// kernel consumes only the cached tree (its vectors were already shipped
/// by the local-MST phase); under the dense kernel it consumes the
/// subset's vectors. `pub(crate)` so the remote proxy's bare `solve` path
/// shares this decision instead of re-deriving it.
pub(crate) fn dense_shipment(job: &PairJob, has_cache: bool) -> Shipment {
    if job.i == job.j {
        if has_cache {
            Shipment { tree_i: true, ..Default::default() }
        } else {
            Shipment { vec_i: true, ..Default::default() }
        }
    } else {
        Shipment { vec_i: true, vec_j: true, tree_i: has_cache, tree_j: has_cache }
    }
}

/// The resident-set shipment: the same per-subset payload as
/// [`dense_shipment`], restricted to subsets the executing worker does not
/// already hold, which are marked resident afterwards. Per job this is ≤
/// the dense model by construction (the per-subset terms are identical),
/// so total affinity scatter can never exceed the dense model.
fn residual_shipment(job: &PairJob, has_cache: bool, resident: &mut [bool]) -> Shipment {
    let (i, j) = (job.i as usize, job.j as usize);
    let mut ship = Shipment::default();
    if i == j {
        if !resident[i] {
            resident[i] = true;
            if has_cache {
                ship.tree_i = true;
            } else {
                ship.vec_i = true;
            }
        }
        return ship;
    }
    if !resident[i] {
        resident[i] = true;
        ship.vec_i = true;
        ship.tree_i = has_cache;
    }
    if !resident[j] {
        resident[j] = true;
        ship.vec_j = true;
        ship.tree_j = has_cache;
    }
    ship
}

/// Wire bytes of one pair-job scatter under `ship`: exactly the length of
/// the `PairAssign` frame the remote proxy encodes for it (header + the
/// shipped sections) — the arithmetic delegates to [`crate::net::wire`], so
/// the modeled charge and the measured frame cannot drift.
fn shipment_bytes(
    plan: &ExecPlan,
    job: &PairJob,
    d: usize,
    cache: Option<&LocalMstCache>,
    ship: &Shipment,
) -> u64 {
    let tree_bytes = |k: usize| {
        cache.map_or(0, |c| c.trees[k].len() as u64 * Edge::WIRE_BYTES as u64)
    };
    let mut bytes = HEADER_BYTES;
    if ship.vec_i {
        bytes += subset_payload_bytes(plan, job.i as usize, d);
    }
    if ship.tree_i {
        bytes += tree_bytes(job.i as usize);
    }
    if ship.vec_j {
        bytes += subset_payload_bytes(plan, job.j as usize, d);
    }
    if ship.tree_j {
        bytes += tree_bytes(job.j as usize);
    }
    bytes
}

/// One subset's share of a pair-job scatter: global-id map + vectors.
/// `job_wire_bytes(|S_i| + |S_j|, d) = HEADER_BYTES + Σ` of these, which is
/// what keeps the dense and resident-set models consistent per subset.
fn subset_payload_bytes(plan: &ExecPlan, k: usize, d: usize) -> u64 {
    crate::net::wire::vectors_payload_bytes(plan.parts[k].len(), d)
}

/// Build the local-MST cache through the worker pool: one job per
/// partition, heaviest first — at its anchor worker when affinity is on
/// (idle stealing as fallback), in which case the builder marks the subset
/// resident so the pair phase's byte model does not re-ship it. Scatter
/// charges each subset's vectors exactly once either way; gather charges
/// each returned local tree once. Under a remote transport, pool thread `w`
/// ships the subset as a `LocalJob` frame to remote worker `w` — which
/// keeps it resident and computes the tree over the gathered rows
/// (bit-identical, see [`crate::exec::pair_kernel::subset_mst_gathered`]) —
/// and the `LocalJob`/`LocalDone` frame sizes are exactly the modeled
/// scatter/gather charges. Also returns each pool worker's busy time so
/// the engine can attribute this phase's compute to
/// `RunMetrics::worker_busy` (remote compute is the worker-measured time
/// from the `LocalDone` frame, not the round-trip).
fn build_cache_pooled(
    ds: &Dataset,
    ctx: &BipartiteCtx,
    plan: &ExecPlan,
    n_workers: usize,
    net: &dyn Transport,
    affinity: Option<&AffinityPlan>,
    residents: &[Mutex<Vec<bool>>],
    remote: Option<&TcpTransport>,
) -> anyhow::Result<(LocalMstCache, Vec<Duration>)> {
    let t = Instant::now();
    let p = plan.parts.len();
    let queue = match affinity {
        Some(aff) => JobQueue::with_decks(aff.local_decks.clone()),
        None => {
            let mut order: Vec<usize> = (0..p).collect();
            order.sort_by(|&a, &b| plan.parts[b].len().cmp(&plan.parts[a].len()).then(a.cmp(&b)));
            JobQueue::new(order)
        }
    };
    let counter = CountingMetric::new(ctx.kind);
    let slots: Vec<Mutex<Option<Vec<Edge>>>> = (0..p).map(|_| Mutex::new(None)).collect();
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let n_spawn = n_workers.min(p);
    let busy: Vec<Mutex<Duration>> = (0..n_spawn).map(|_| Mutex::new(Duration::ZERO)).collect();
    std::thread::scope(|scope| {
        let queue_ref = &queue;
        let counter_ref = &counter;
        let slots_ref = &slots;
        let errors_ref = &errors;
        for (w, busy_slot) in busy.iter().enumerate() {
            let resident = &residents[w];
            scope.spawn(move || {
                while let Some((k, _stolen)) = queue_ref.pop_for(w) {
                    let ids = &plan.parts[k];
                    net.charge(job_wire_bytes(ids.len(), ds.d), Direction::Scatter);
                    if affinity.is_some() {
                        // this worker now holds the subset's vectors (and
                        // will hold its tree): seed the pair-phase model
                        resident.lock().unwrap()[k] = true;
                    }
                    let tree = if let Some(tcp) = remote {
                        let msg = Message::LocalJob {
                            part: k as u32,
                            global_ids: ids.clone(),
                            points: ds.gather(ids),
                        };
                        let reply = tcp
                            .send_to(w, &msg, Direction::Scatter)
                            .and_then(|_| tcp.recv_from(w));
                        match reply {
                            Ok(Message::LocalDone { part, edges, compute })
                                if part as usize == k =>
                            {
                                *busy_slot.lock().unwrap() += compute;
                                edges
                            }
                            Ok(other) => {
                                errors_ref.lock().unwrap().push(format!(
                                    "worker {w}: expected LocalDone for subset {k}, got {other:?}"
                                ));
                                return;
                            }
                            Err(e) => {
                                errors_ref.lock().unwrap().push(format!(
                                    "worker {w}: local-MST job for subset {k} failed: {e:#}"
                                ));
                                return;
                            }
                        }
                    } else {
                        let t_job = Instant::now();
                        let tree = subset_mst(
                            ds.as_slice(),
                            ds.d,
                            ctx.block.as_ref(),
                            &ctx.aux,
                            counter_ref,
                            ids,
                        );
                        *busy_slot.lock().unwrap() += t_job.elapsed();
                        tree
                    };
                    net.charge(
                        HEADER_BYTES + tree.len() as u64 * Edge::WIRE_BYTES as u64,
                        Direction::Gather,
                    );
                    *slots_ref[k].lock().unwrap() = Some(tree);
                }
            });
        }
    });
    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        anyhow::bail!("local-MST phase failed: {}", errors.join("; "));
    }
    let trees: Vec<Vec<Edge>> = slots
        .into_iter()
        .enumerate()
        .map(|(k, s)| {
            s.into_inner()
                .unwrap()
                .ok_or_else(|| anyhow::anyhow!("subset {k} local MST missing (worker failure?)"))
        })
        .collect::<anyhow::Result<_>>()?;
    // The remote workers did the cache evaluations; the count is exact from
    // the partition shape (Prim over m points is always C(m, 2) pairs) —
    // identical to what the in-process CountingMetric records.
    let evals = if remote.is_some() {
        plan.parts
            .iter()
            .map(|p| {
                let m = p.len() as u64;
                m * m.saturating_sub(1) / 2
            })
            .sum()
    } else {
        counter.evals()
    };
    let busy: Vec<Duration> = busy.into_iter().map(|b| b.into_inner().unwrap()).collect();
    Ok((LocalMstCache { trees, evals, build_time: t.elapsed() }, busy))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KernelChoice;
    use crate::data::generators::uniform;
    use crate::net::NetSim;
    use crate::decomp::decomposed_mst;
    use crate::dense::PrimDense;
    use crate::geometry::MetricKind;
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    fn int_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(25) as f32 - 12.0).collect();
        Dataset::new(n, d, data)
    }

    #[test]
    fn bipartite_serial_matches_dense_serial() {
        let ds = int_dataset(500, 70, 6);
        for parts in [1usize, 2, 3, 5, 7] {
            let cfg = DecompConfig { parts, ..Default::default() };
            let dense = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
            let bip = decomposed_mst_bipartite(&ds, &cfg, MetricKind::SqEuclid);
            assert_eq!(
                normalize_tree(&dense.mst),
                normalize_tree(&bip.mst),
                "parts={parts}"
            );
            let n = ds.n as u64;
            assert_eq!(bip.dist_evals, n * (n - 1) / 2, "parts={parts}: exactly C(n,2)");
            if parts >= 3 {
                assert!(
                    bip.dist_evals < dense.dist_evals,
                    "parts={parts}: bipartite {} !< dense {}",
                    bip.dist_evals,
                    dense.dist_evals
                );
            }
        }
    }

    #[test]
    fn pooled_bipartite_matches_pooled_dense_all_worker_counts() {
        let ds = int_dataset(501, 80, 5);
        let mut cfg = RunConfig {
            parts: 4,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let dense = execute_pooled(&ds, &cfg, &net).unwrap();
        cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
        for workers in [1usize, 3, 6] {
            cfg.workers = workers;
            let net = NetSim::new(cfg.net.clone());
            let bip = execute_pooled(&ds, &cfg, &net).unwrap();
            assert_eq!(
                normalize_tree(&dense.mst),
                normalize_tree(&bip.mst),
                "workers={workers}"
            );
            let n = ds.n as u64;
            assert_eq!(bip.metrics.dist_evals, n * (n - 1) / 2, "workers={workers}");
            assert_eq!(
                bip.metrics.local_mst_evals + bip.metrics.pair_evals,
                bip.metrics.dist_evals
            );
            assert!(bip.metrics.local_mst_evals > 0);
        }
    }

    #[test]
    fn stream_reduce_matches_batch() {
        let ds = int_dataset(502, 60, 4);
        let mut cfg = RunConfig {
            parts: 5,
            workers: 3,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let batch = execute_pooled(&ds, &cfg, &net).unwrap();
        cfg.stream_reduce = true;
        let net = NetSim::new(cfg.net.clone());
        let streamed = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(normalize_tree(&batch.mst), normalize_tree(&streamed.mst));
        assert_eq!(batch.metrics.union_edges, streamed.metrics.union_edges);
        assert!(streamed.metrics.stream_reduce);
        // streaming also composes with worker-local ⊕-reduction
        cfg.reduce_tree = true;
        let net = NetSim::new(cfg.net.clone());
        let both = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(normalize_tree(&batch.mst), normalize_tree(&both.mst));
    }

    #[test]
    fn lpt_scatter_bytes_match_dense_model() {
        // With affinity routing off, the pull-based scheduler must charge
        // the identical per-job scatter the eager round-robin leader
        // charged — the dense model stays available byte-for-byte.
        let ds = uniform(96, 7, 1.0, Pcg64::seeded(503));
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            strategy: crate::decomp::PartitionStrategy::Block,
            affinity: false,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        let m = 2 * 96 / 4;
        let per_job = 16 + m as u64 * 4 + (m * 7) as u64 * 4;
        assert_eq!(out.metrics.scatter_bytes, 6 * per_job);
        assert_eq!(out.metrics.scatter_saved_bytes, 0, "dense model saves nothing");
        assert_eq!(out.metrics.jobs_stolen, 0, "single shared deck: nothing counts as stolen");
    }

    /// The resident-set invariant that makes the affinity model auditable:
    /// per job, charged + saved equals the dense model exactly, so for any
    /// seed/strategy/worker count `affinity.scatter + affinity.saved ==
    /// dense.scatter` — and the tree is unchanged.
    #[test]
    fn affinity_scatter_plus_saved_equals_dense_model() {
        let ds = int_dataset(505, 90, 6);
        for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            for workers in [1usize, 2, 4] {
                let mut cfg = RunConfig {
                    parts: 5,
                    workers,
                    kernel: KernelChoice::PrimDense,
                    pair_kernel,
                    ..Default::default()
                };
                cfg.affinity = false;
                let net = NetSim::new(cfg.net.clone());
                let dense = execute_pooled(&ds, &cfg, &net).unwrap();
                cfg.affinity = true;
                let net = NetSim::new(cfg.net.clone());
                let aff = execute_pooled(&ds, &cfg, &net).unwrap();
                assert_eq!(
                    normalize_tree(&dense.mst),
                    normalize_tree(&aff.mst),
                    "{pair_kernel:?} workers={workers}: affinity must not change the tree"
                );
                assert_eq!(
                    aff.metrics.scatter_bytes + aff.metrics.scatter_saved_bytes,
                    dense.metrics.scatter_bytes,
                    "{pair_kernel:?} workers={workers}: charged + saved == dense model"
                );
                assert!(
                    aff.metrics.scatter_bytes <= dense.metrics.scatter_bytes,
                    "{pair_kernel:?} workers={workers}: affinity can never charge more"
                );
                assert_eq!(aff.metrics.dist_evals, dense.metrics.dist_evals);
            }
        }
    }

    /// parts ≥ 4 with few workers: by pigeonhole some worker runs more pair
    /// jobs than a maximum matching over the subsets, so at least one job
    /// must share a subset with an earlier job on that worker — the
    /// resident-set model saves strictly positive bytes, for both kernels.
    #[test]
    fn affinity_saves_strictly_for_parts_ge_4() {
        let ds = int_dataset(506, 80, 5);
        for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            for workers in [1usize, 2] {
                let cfg = RunConfig {
                    parts: 4,
                    workers,
                    kernel: KernelChoice::PrimDense,
                    pair_kernel,
                    ..Default::default()
                };
                let net = NetSim::new(cfg.net.clone());
                let out = execute_pooled(&ds, &cfg, &net).unwrap();
                assert!(
                    out.metrics.scatter_saved_bytes > 0,
                    "{pair_kernel:?} workers={workers}: expected strict scatter savings"
                );
            }
        }
    }

    #[test]
    fn bipartite_panel_cache_metrics_populated() {
        let ds = int_dataset(507, 64, 4);
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            strategy: crate::decomp::PartitionStrategy::Block,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        // 6 cross jobs × 2 panel probes, however they land on the workers
        assert_eq!(out.metrics.panel_hits + out.metrics.panel_misses, 12);
        // 2 workers, 6 jobs: some worker ran ≥ 3 jobs over 4 subsets, which
        // cannot be pairwise disjoint — at least one probe hit
        assert!(out.metrics.panel_hits > 0, "panel cache never hit");
        assert!(out.metrics.panel_hit_rate() > 0.0);
    }

    #[test]
    fn stream_reduce_fold_metrics_populated() {
        let ds = int_dataset(508, 60, 4);
        let cfg = RunConfig {
            parts: 5,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            stream_reduce: true,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(out.metrics.reduce_folds, 10, "one fold per pair tree");
        assert!(out.metrics.reduce_fold_edges > 0);
        assert!(
            out.metrics.reduce_fold_edges <= out.metrics.reduce_folds as u64 * 2 * (ds.n as u64 - 1),
            "streaming folds stay O(|V|) each: {} edges over {} folds",
            out.metrics.reduce_fold_edges,
            out.metrics.reduce_folds
        );
    }

    #[test]
    fn bipartite_phase_metrics_populated() {
        let ds = int_dataset(504, 64, 4);
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            strategy: crate::decomp::PartitionStrategy::Block,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(out.metrics.pair_kernel, "bipartite-merge");
        assert!(out.metrics.kernel.contains("bipartite-merge"), "{}", out.metrics.kernel);
        assert!(out.metrics.phase_local_mst > Duration::ZERO);
        assert!(out.metrics.phase_pair > Duration::ZERO);
        // 4 partitions of 16: cache = 4 * C(16,2), pairs = 6 * 16 * 16
        assert_eq!(out.metrics.local_mst_evals, 4 * (16 * 15 / 2));
        assert_eq!(out.metrics.pair_evals, 6 * 16 * 16);
    }
}
