//! The pair-job execution engine: the single owner of
//! partition → schedule → solve → reduce for *both* front-ends.
//!
//! [`run_serial`] drives a [`PairSolver`] on the calling thread in the
//! paper's schedule order (the `decomp::decomposed_mst` reference path);
//! [`execute_pooled`] drives a `std::thread` worker pool with cost-LPT
//! dealing + idle stealing over the same plan, with every scatter/gather
//! charged to a [`Transport`] (the `coordinator::run_distributed` path —
//! the simulated [`NetSim`](crate::net::NetSim) byte model by default, or
//! real TCP links via [`execute_pooled_remote`], where each pool thread
//! drives a remote `demst worker` process through a
//! [`RemoteLink`]). Per-phase timings and evaluation counters land in
//! [`RunMetrics`].
//!
//! Pooled flow, bipartite-merge kernel:
//!
//! ```text
//! phase local-MST:  pool over partitions   — MST(S_k) once each, cached
//! phase pair:       pool over pair jobs    — filtered Prim per (S_i, S_j)
//! phase reduce:     leader                 — streaming ⊕ or batch Kruskal
//! ```
//!
//! The dense kernel skips the first phase and solves each pair with a full
//! d-MST over the gathered union, exactly as before the refactor.
//!
//! ## Remote execution: pipelined and elastic
//!
//! The remote driver ([`execute_pooled_remote`]) keeps up to
//! `cfg.pipeline_window` `PairAssign` frames outstanding per link before
//! reading the matching replies, overlapping scatter with remote compute
//! (window 1 restores the strict rendezvous; frames per link stay FIFO, so
//! window size cannot change which bytes travel — only when).
//!
//! When a worker's link dies mid-run, its claimed-but-undelivered jobs
//! (the in-flight window, plus — in reduce mode — every job folded into
//! its never-gathered local tree) go back to the [`JobQueue`]'s return
//! lane, its unclaimed deck is abandoned to the lane, and the surviving
//! fleet drains the lane; every pair job is still *recorded exactly once*
//! at the leader, so the final tree is bit-identical to a failure-free
//! run. `RunMetrics::{worker_failures, jobs_reassigned}` witness the
//! recovery.
//!
//! ## Sharded execution: the leader never holds vectors
//!
//! Under [`execute_pooled_sharded`] the engine runs with **no dataset at
//! all**: the plan comes from a shard manifest, every subset's vectors are
//! resident on the workers that loaded them from local shard files
//! (advertised in the versioned handshake, seeding the resident-set model), and
//! scheduling is restricted to workers holding *both* subsets of a job
//! ([`ExecPlan::affinity_for_holders`]). Phase 1 dispatches header-only
//! `LocalAssign` frames; pair scatter ships at most cached local *trees*
//! (edges, never vectors). `RunMetrics::leader_ingest_bytes` — the vector
//! payload that passed through the leader — is 0 by construction, with
//! `shard_local_bytes` accounting what the fleet loaded from disk instead.

use super::pair_kernel::{
    subset_mst, BipartiteCtx, BipartitePairSolver, DensePairSolver, LocalMstCache, PairSolver,
    Shipment, SolverFinal,
};
use super::plan::{AffinityPlan, ExecPlan};
use super::scheduler::JobQueue;
use crate::config::{PairKernelChoice, ReduceTopology, RunConfig};
use crate::coordinator::messages::{job_wire_bytes, Message, FOLD_KEEP, HEADER_BYTES};
use crate::coordinator::metrics::RunMetrics;
use crate::data::Dataset;
use crate::decomp::reduction::{reduce_trees_with, tree_merge, StreamReducer};
use crate::decomp::{pair_count, DecompConfig, DecompOutput, PairJob};
use crate::geometry::simd::{self, Isa};
use crate::geometry::CountingMetric;
use crate::graph::Edge;
use crate::mst::kruskal;
use crate::net::remote::RemoteLink;
use crate::net::wire::PEER_ENTRY_BYTES;
use crate::net::{Direction, NetCounters, TcpTransport, Transport};
use std::collections::{HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Spare [`Fleet`] slots pre-allocated on remote runs for workers admitted
/// mid-run (`Join`/`AdmitAck`): the per-worker vectors are shared immutably
/// across the worker scope, so they must never reallocate — admissions
/// beyond this many extra links stay connected but idle.
const ADMIT_SPARE: usize = 4;

/// How often the remote gather loop wakes to check the transport for
/// newly admitted links when no frame is pending.
const ADMIT_POLL: Duration = Duration::from_millis(25);

/// Resolve the worker count: explicit, else one per pair job capped at the
/// machine's parallelism.
pub fn resolve_workers(cfg: &RunConfig) -> usize {
    let jobs = pair_count(cfg.parts).max(1);
    if cfg.workers > 0 {
        cfg.workers.min(jobs)
    } else {
        let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(4);
        jobs.min(cores)
    }
}

/// Output of a serial engine run.
pub struct SerialRun {
    /// the exact global MSF
    pub mst: Vec<Edge>,
    /// total edges across all pair trees (the `O(|V|·|P|)` gather payload)
    pub union_edges: usize,
    /// per-pair trees in schedule order, if requested
    pub pair_trees: Vec<Vec<Edge>>,
    /// pair jobs executed
    pub jobs: usize,
}

/// Drive `solver` over the plan's jobs on the calling thread (schedule
/// order), then take the sparse MST of the union.
pub fn run_serial(
    n: usize,
    plan: &ExecPlan,
    solver: &mut dyn PairSolver,
    keep_pair_trees: bool,
) -> SerialRun {
    let mut union_edges: Vec<Edge> = Vec::new();
    let mut pair_trees = Vec::new();
    for job in &plan.jobs {
        let tree = solver.solve(plan, job);
        union_edges.extend_from_slice(&tree);
        if keep_pair_trees {
            pair_trees.push(tree);
        }
    }
    let union_count = union_edges.len();
    let mst = kruskal(n, &union_edges);
    SerialRun { mst, union_edges: union_count, pair_trees, jobs: plan.n_jobs() }
}

/// Serial decomposed MST with the bipartite-merge pair kernel: local MSTs
/// cached once, pair jobs solved by filtered Prim. Returns the same
/// [`DecompOutput`] as the dense reference path, with `dist_evals` =
/// `Σ_k |S_k|(|S_k|-1)/2 + Σ_{i<j} |S_i|·|S_j| = n(n-1)/2` exactly.
pub fn decomposed_mst_bipartite(
    ds: &Dataset,
    cfg: &DecompConfig,
    kind: crate::geometry::MetricKind,
) -> DecompOutput {
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    let ctx = BipartiteCtx::new(ds, kind);
    let cache = LocalMstCache::build_serial(ds, &ctx, &plan.parts);
    let mut solver = BipartitePairSolver::new(ds, &ctx, &cache);
    let run = run_serial(ds.n, &plan, &mut solver, cfg.keep_pair_trees);
    DecompOutput {
        mst: run.mst,
        union_edges: run.union_edges,
        dist_evals: cache.evals + solver.dist_evals(),
        jobs: run.jobs,
        pair_trees: run.pair_trees,
        part_sizes: plan.part_sizes(),
    }
}

/// Output of a pooled engine run.
pub struct PooledRun {
    pub mst: Vec<Edge>,
    pub metrics: RunMetrics,
    pub workers: usize,
}

/// What of one subset the executing worker already holds under the
/// resident-set model. On leader-resident runs vectors and cached tree
/// always travel (and are marked) together, reproducing the historical
/// single-flag model byte-for-byte; on sharded runs vectors are seeded
/// from the handshake advertisements while trees become resident only
/// where phase 1 built (or later shipped) them.
#[derive(Clone, Copy, Debug, Default)]
struct Held {
    vecs: bool,
    tree: bool,
}

/// Shared elastic-fleet bookkeeping: which links died, how many jobs were
/// recorded at the leader, and the run-level abort latch.
struct Fleet {
    dead: Vec<AtomicBool>,
    /// reduce mode: worker's shutdown rendezvous succeeded — its remotely
    /// ⊕-folded results are durably at the leader
    finished: Vec<AtomicBool>,
    done_jobs: AtomicUsize,
    expected_jobs: usize,
    failures: AtomicU32,
    reassigned: AtomicU32,
    /// failures whose error chain carried [`crate::net::STALL_MARK`] — the
    /// link did not die, its peer went silent past the liveness deadline
    stalls: AtomicU32,
    abort: AtomicBool,
    /// tree/ring topologies: the job indices whose folded results currently
    /// ride worker `w`'s partial MSF — its own acked jobs plus everything
    /// inherited through ⊕-fold hops. A dead (or fold-failed) worker's bag
    /// returns to the exactly-once lane wholesale.
    fold_jobs: Vec<Mutex<Vec<usize>>>,
    /// how many peer partials worker `w` must await before its own fold hop
    /// (incremented when a lower worker's `FoldDone { ok: true }` targeted it)
    fold_expect: Vec<AtomicU32>,
    /// jobs re-run after a fold failure whose original runner had already
    /// reported them in its `WorkerDone.jobs_run` — subtracted from
    /// `RunMetrics::jobs` so the exactly-once audit stays exact
    fold_rerun_credit: AtomicU32,
}

impl Fleet {
    /// `spares` extra slots sit past the initial `workers`, pre-marked
    /// dead **and** finished: every elastic gate ignores them (no failure
    /// is counted) until a mid-run admission flips both flags back off.
    fn new(workers: usize, spares: usize, expected_jobs: usize) -> Self {
        let slots = workers + spares;
        Self {
            dead: (0..slots).map(|i| AtomicBool::new(i >= workers)).collect(),
            finished: (0..slots).map(|i| AtomicBool::new(i >= workers)).collect(),
            done_jobs: AtomicUsize::new(0),
            expected_jobs,
            failures: AtomicU32::new(0),
            reassigned: AtomicU32::new(0),
            stalls: AtomicU32::new(0),
            abort: AtomicBool::new(false),
            fold_jobs: (0..slots).map(|_| Mutex::new(Vec::new())).collect(),
            fold_expect: (0..slots).map(|_| AtomicU32::new(0)).collect(),
            fold_rerun_credit: AtomicU32::new(0),
        }
    }

    // Coordination flags use SeqCst: the elastic gates (`complete`,
    // `lower_all_settled`, `stranded`) read *combinations* of these
    // atomics, and a relaxed reordering between e.g. `done_jobs -= k` and
    // `dead[w] = true` would let a peer observe "complete and settled"
    // mid-failover and disperse with recoverable jobs stranded.

    fn alive(&self) -> Vec<bool> {
        self.dead.iter().map(|d| !d.load(Ordering::SeqCst)).collect()
    }

    /// Workers that can still claim returned jobs: alive **and** not yet
    /// through their shutdown rendezvous (a finished driver has exited its
    /// claim loop for good, so it can never pick up a stranded job).
    fn available(&self) -> Vec<bool> {
        self.dead
            .iter()
            .zip(&self.finished)
            .map(|(d, f)| !d.load(Ordering::SeqCst) && !f.load(Ordering::SeqCst))
            .collect()
    }

    fn complete(&self) -> bool {
        self.done_jobs.load(Ordering::SeqCst) >= self.expected_jobs
    }

    fn aborted(&self) -> bool {
        self.abort.load(Ordering::SeqCst)
    }

    /// Mark `w` dead. Call only **after** every recovery side effect of
    /// the failure (done-count rollback, return-lane pushes) is in place:
    /// peers treat a dead worker as settled, so the flag must be the last
    /// thing they can observe.
    fn fail_worker(&self, w: usize) {
        self.dead[w].store(true, Ordering::SeqCst);
        self.failures.fetch_add(1, Ordering::Relaxed);
    }
}

/// The run's byte witnesses, accumulated by every scatter site:
/// `saved` is the resident-set model's savings vs the dense model,
/// `ingest` the vector payload that passed *through the leader's memory*,
/// and `data` every scatter-direction payload byte beyond frame headers
/// (vectors **and** inline trees) — the quantity the peer data plane
/// drives to zero on sharded routed runs
/// (`RunMetrics::leader_data_bytes == 0`).
#[derive(Default)]
struct ByteWitness {
    saved: AtomicU64,
    ingest: AtomicU64,
    data: AtomicU64,
}

/// The peer data plane's leader-side routing state, built after phase 1
/// when `cfg.effective_peer_route()` is on and the bipartite kernel cached
/// local trees: which worker anchors each subset, which subsets have been
/// demoted to inline shipping after a failed fetch, and — on the simulated
/// transport — the modeled peer-link set (a `PeerHello` is charged once
/// per directed link, exactly like the real connection cache).
struct RouteCtx<'a> {
    /// subset `k`'s building anchor (always a worker on remote runs)
    builders: Vec<u16>,
    /// the advertised peer listener port per worker (0 = no listener,
    /// never routable); all-1 placeholder on the simulated transport
    ports: Vec<u16>,
    /// subsets demoted to inline tree shipping (dead or refusing anchor)
    no_route: Vec<AtomicBool>,
    /// directed peer links already opened, for `PeerHello` accounting —
    /// shared between the routed-fetch model and the fold model
    links: Mutex<HashSet<(usize, usize)>>,
    /// simulated transport: charge the modeled peer traffic to the
    /// counters (a real transport measures it worker-side instead)
    model: bool,
    fleet: &'a Fleet,
}

impl RouteCtx<'_> {
    /// Can subset `k`'s tree travel over the peer plane right now?
    fn routable(&self, k: usize) -> bool {
        let b = self.builders[k];
        (b as usize) < self.ports.len()
            && !self.no_route[k].load(Ordering::Relaxed)
            && !self.fleet.dead[b as usize].load(Ordering::SeqCst)
            && self.ports[b as usize] != 0
    }

    /// Model one peer transfer of `payload_bytes` riding a `TreeFetch` or
    /// fold hop on the link `from → to`, charging the `PeerHello` opener
    /// the first time the link is used. No-op on real transports (workers
    /// count their actual peer traffic and report it in `WorkerDone`).
    /// Peer bytes are charged without a message increment: the leader-link
    /// message counter must stay transport-identical, and real peer frames
    /// never cross the leader's counters.
    fn model_peer_hop(&self, counters: &NetCounters, from: usize, to: usize, frame_bytes: u64) {
        if !self.model {
            return;
        }
        if self.links.lock().unwrap().insert((from, to)) {
            counters.add_bytes(HEADER_BYTES, Direction::Peer); // PeerHello
        }
        counters.add_bytes(frame_bytes, Direction::Peer);
    }
}

/// One worker's ⊕-fold ship target under `topology`, over the currently
/// alive fleet: `Ring` ships to the next alive id, `Tree` follows a
/// mirrored binomial schedule. Both root at the **highest** alive id —
/// drivers settle in ascending id order, so the root is the last to fold
/// and every partial has arrived by then. Ships always ascend worker ids.
fn fold_target(topology: ReduceTopology, alive: &[bool], w: usize) -> u16 {
    let ids: Vec<usize> =
        alive.iter().enumerate().filter(|&(_, &a)| a).map(|(i, _)| i).collect();
    let pos = ids.iter().position(|&i| i == w).expect("fold_target: self must be alive");
    let m = ids.len();
    match topology {
        ReduceTopology::Leader => FOLD_KEEP,
        ReduceTopology::Ring => {
            if pos + 1 < m {
                ids[pos + 1] as u16
            } else {
                FOLD_KEEP
            }
        }
        ReduceTopology::Tree => {
            // Mirror the classic binomial reduction so the root lands on
            // the highest id: position q = (m-1) - pos counts down from the
            // root, and q ships to q - lowbit(q).
            let q = (m - 1) - pos;
            if q == 0 {
                FOLD_KEEP
            } else {
                let q_target = q - (q & q.wrapping_neg());
                ids[(m - 1) - q_target] as u16
            }
        }
    }
}

/// Simulated-transport model of a tree/ring reduction: walk the fold
/// schedule over the workers' actual partials (ascending id, exactly the
/// order the real drivers settle in), `tree_merge`-ing each shipped
/// partial into its target and charging the modeled traffic — two 16-byte
/// control frames per worker (`FoldShip` + `FoldDone`), one peer hop per
/// ship (plus `PeerHello` per new link), and the root partial's edge
/// payload as gather bytes riding the already-charged bare `WorkerDone`
/// frame. Returns the root's folded MSF, which **is** the reduction output
/// — the byte model and the answer come from the same folds.
fn model_fold_topology(
    n: usize,
    topology: ReduceTopology,
    n_workers: usize,
    partials: Vec<(usize, Vec<Edge>)>,
    net: &dyn Transport,
    counters: &NetCounters,
    route: Option<&RouteCtx<'_>>,
) -> Vec<Edge> {
    let mut trees: Vec<Vec<Edge>> = vec![Vec::new(); n_workers];
    for (w, t) in partials {
        trees[w] = t;
    }
    let alive = vec![true; n_workers];
    let links: Mutex<HashSet<(usize, usize)>> = Mutex::new(HashSet::new());
    let mut root = 0usize;
    for w in 0..n_workers {
        // FoldShip directive + FoldDone reply, leader-link control frames
        net.charge(HEADER_BYTES, Direction::Control);
        net.charge(HEADER_BYTES, Direction::Control);
        let to = fold_target(topology, &alive, w);
        if to == FOLD_KEEP {
            root = w;
            continue;
        }
        let to = to as usize;
        // PeerHello once per directed link — against the routed-fetch
        // link set when routing also ran, so a link the fetch phase
        // already opened is not re-charged.
        let fresh = match route {
            Some(rc) => rc.links.lock().unwrap().insert((w, to)),
            None => links.lock().unwrap().insert((w, to)),
        };
        if fresh {
            counters.add_bytes(HEADER_BYTES, Direction::Peer);
        }
        let shipped = std::mem::take(&mut trees[w]);
        counters
            .add_bytes(HEADER_BYTES + shipped.len() as u64 * Edge::WIRE_BYTES as u64, Direction::Peer);
        trees[to] = if trees[to].is_empty() {
            shipped
        } else if shipped.is_empty() {
            std::mem::take(&mut trees[to])
        } else {
            tree_merge(n, &trees[to], &shipped)
        };
    }
    let root_tree = std::mem::take(&mut trees[root]);
    // The root's folded MSF rides its bare `WorkerDone` frame: payload
    // bytes accrue to gather with no extra message.
    counters.add_bytes(root_tree.len() as u64 * Edge::WIRE_BYTES as u64, Direction::Gather);
    root_tree
}

/// The pooled engine over the simulated transport: worker threads claim
/// jobs from per-worker affinity decks (cost-LPT within each deck, idle
/// stealing as fallback; one shared LPT queue when `cfg.affinity` is off);
/// the leader gathers trees (streaming or buffered) and finishes the
/// reduction. All traffic is charged to `net` — under the resident-set
/// model only payload the executing worker is missing, with the dense
/// model's difference recorded in `RunMetrics::scatter_saved_bytes`.
pub fn execute_pooled(
    ds: &Dataset,
    cfg: &RunConfig,
    net: &dyn Transport,
) -> anyhow::Result<PooledRun> {
    let plan = ExecPlan::new(ds, cfg.parts, cfg.strategy, cfg.seed);
    execute_pooled_inner(Some(ds), ds.n, ds.d, cfg, net, None, plan)
}

/// The identical pooled engine run against **remote worker processes**:
/// pool thread `w` drives remote worker `w` through a [`RemoteLink`] over
/// `tcp`'s socket, with up to `cfg.pipeline_window` jobs in flight per
/// link. [`Transport::charge`] no-ops on the TCP transport — the counters
/// are fed by the actual encoded frames, which equal the modeled charges
/// byte-for-byte because [`Message::wire_bytes`] is computed from the real
/// wire encoding.
///
/// `plan` is the **same plan the handshake announced**: the caller
/// ([`crate::net::launch::serve`]) partitions once, tells every worker the
/// partition layout in its `Setup` frame, and hands the identical plan
/// here — so the section lengths workers derive for `PairAssign` frames
/// can never drift from the jobs the engine actually ships.
pub fn execute_pooled_remote(
    ds: &Dataset,
    cfg: &RunConfig,
    tcp: &TcpTransport,
    plan: ExecPlan,
) -> anyhow::Result<PooledRun> {
    execute_pooled_inner(Some(ds), ds.n, ds.d, cfg, tcp, Some(tcp), plan)
}

/// The sharded pooled engine: same remote execution, but the leader holds
/// **no vectors** — `plan` comes from a shard manifest (`n`/`d` likewise)
/// and every subset is resident on the workers whose handshake advertised
/// it. Scheduling is restricted to workers holding both subsets of a job;
/// pair scatter ships at most cached local trees.
pub fn execute_pooled_sharded(
    cfg: &RunConfig,
    tcp: &TcpTransport,
    plan: ExecPlan,
    n: usize,
    d: usize,
) -> anyhow::Result<PooledRun> {
    execute_pooled_inner(None, n, d, cfg, tcp, Some(tcp), plan)
}

fn execute_pooled_inner(
    ds: Option<&Dataset>,
    n: usize,
    d: usize,
    cfg: &RunConfig,
    net: &dyn Transport,
    remote: Option<&TcpTransport>,
    plan: ExecPlan,
) -> anyhow::Result<PooledRun> {
    let t_start = Instant::now();
    let sharded = ds.is_none();
    debug_assert!(!sharded || remote.is_some(), "sharded runs are remote by definition");
    let n_workers = resolve_workers(cfg);
    if let Some(tcp) = remote {
        // `>=`: a replacement worker may have been admitted between the
        // launch handshake and here — extra links activate in the gather
        // loop, exactly like any other mid-run admission.
        anyhow::ensure!(
            tcp.len() >= n_workers,
            "transport holds {} worker links but the plan resolves to {n_workers} workers",
            tcp.len()
        );
    }
    let counters = net.counters();
    let p = plan.parts.len();

    // Sharded residency: which subsets each worker's handshake advertised,
    // and the vector payload the fleet loaded from local shard files
    // instead of receiving over the wire (per worker copy — replicated
    // shards count once per replica, they were each read from disk).
    let holders: Option<Vec<Vec<bool>>> = if sharded {
        let tcp = remote.expect("sharded implies remote");
        let mut holders = vec![vec![false; p]; n_workers];
        for (w, row) in holders.iter_mut().enumerate() {
            for k in tcp.advertised(w) {
                let k = k as usize;
                anyhow::ensure!(
                    k < p,
                    "worker {w} advertised shard {k} but the manifest has {p} shards"
                );
                row[k] = true;
            }
        }
        Some(holders)
    } else {
        None
    };
    let shard_local_bytes: u64 = holders.as_ref().map_or(0, |h| {
        h.iter()
            .flat_map(|row| row.iter().enumerate())
            .filter(|&(_, &held)| held)
            .map(|(k, _)| crate::net::wire::vectors_payload_bytes(plan.parts[k].len(), d))
            .sum()
    });

    // Subset-affinity routing + resident-set byte model (cfg.affinity):
    // each subset gets an anchor worker, jobs land on the anchor of their
    // larger subset, and each worker remembers which subsets (vectors +
    // cached tree) it already holds — residency persists from the local-MST
    // phase into the pair phase, and only the *missing* payload is charged.
    // Sharded runs use the holder-constrained variant and a capability mask.
    let (affinity, caps): (Option<AffinityPlan>, Option<Vec<Vec<bool>>>) =
        if let Some(h) = &holders {
            let (aff, caps) = plan.affinity_for_holders(h)?;
            (Some(aff), Some(caps))
        } else {
            (cfg.affinity.then(|| plan.affinity(n_workers)), None)
        };
    // Spare residency/fleet slots back the mid-run admission path: the
    // vectors are shared by reference across the worker scope, so they are
    // sized once for every link that could ever activate.
    let spares = if remote.is_some() { ADMIT_SPARE } else { 0 };
    let residents: Vec<Mutex<Vec<Held>>> =
        (0..n_workers + spares).map(|_| Mutex::new(vec![Held::default(); p])).collect();
    if let Some(h) = &holders {
        for (w, row) in h.iter().enumerate() {
            let mut res = residents[w].lock().unwrap();
            for (k, &held) in row.iter().enumerate() {
                res[k].vecs = held;
            }
        }
    }
    let witness = ByteWitness::default();
    let fleet = Fleet::new(n_workers, spares, plan.n_jobs());
    let topology = cfg.reduce_topology;
    let topology_mode = cfg.reduce_tree && topology != ReduceTopology::Leader;
    // Simulated transport: the fold schedule is modeled (and *computed*)
    // leader-side after the gather loop, from the workers' actual partials.
    let sim_topology = topology_mode && remote.is_none();

    let panel_settings = cfg.panel_settings();
    let mut metrics = RunMetrics {
        worker_busy: vec![Duration::ZERO; n_workers],
        kernel: crate::runtime::exec_kernel_label(cfg),
        kernel_fallback: crate::runtime::kernel_fallback_note(cfg),
        pair_kernel: cfg.pair_kernel.name().to_string(),
        stream_reduce: cfg.stream_reduce,
        transport: if remote.is_some() { "tcp" } else { "sim" }.to_string(),
        pipeline_window: if remote.is_some() { cfg.pipeline_window.max(1) as u32 } else { 1 },
        shard_local_bytes,
        sharded,
        ..Default::default()
    };
    if cfg.pair_kernel == PairKernelChoice::BipartiteMerge {
        // Leader-resolved panel path; remote workers report theirs over
        // the wire and override this during the gather loop.
        metrics.panel_isa = panel_settings.isa.label().to_string();
        metrics.panel_lanes = panel_settings.isa.lanes() as u32;
        metrics.panel_fallback = simd::panel_fallback_note(cfg.panel_simd);
    }

    // Telemetry: arm the span recorder for the whole run. The leader
    // thread holds the token; every pooled thread adopts it at spawn.
    // Off (the default) costs each span site one relaxed atomic load and
    // zero allocations, so the job hot path does not move.
    let obs_run = cfg.obs.trace.then(crate::obs::begin_run);
    // Leader-side gather log (job id, runner, compute, receive time):
    // jobs whose runner dies before the shutdown rendezvous never ship a
    // span block, so their Job spans are synthesized from this at the end.
    let mut job_log: Vec<(u32, u16, u64, u64)> = Vec::new();

    // Fleet metrics hub — per run, never process-global, so parallel
    // in-process runs (the test binary) cannot cross-contaminate. Leader
    // threads record straight into `hub.local`; remote workers' cumulative
    // snapshots arrive over the wire (periodic `MetricsPush` absorbed by
    // the transport, plus the final block on `WorkerDone`). Recording is
    // always on — the armed config only gates wire shipping and exposition.
    let hub = Arc::new(crate::obs::metrics::MetricsHub::new());
    if let Some(tcp) = remote {
        tcp.set_metrics_sink(Arc::clone(&hub));
    }
    let metrics_server = match &cfg.obs.metrics_listen {
        Some(listen) => {
            let srv = crate::obs::expose::MetricsServer::start(listen, Arc::clone(&hub))?;
            // `http://` + `/metrics` spelled out so the line is curl-able
            // as printed (scripts/metrics_smoke.sh scrapes mid-run).
            crate::obs::log!(info, "metrics: listening on http://{}/metrics", srv.addr());
            Some(srv)
        }
        None => None,
    };

    // Phase 1 (bipartite-merge only): every partition's local MST, once,
    // through the same worker pool — at its anchor when affinity is on, so
    // the anchor already holds the subset when the pair phase starts.
    let mut builders: Vec<u16> = Vec::new();
    let bip: Option<(Option<BipartiteCtx>, LocalMstCache)> = match cfg.pair_kernel {
        PairKernelChoice::Dense => None,
        PairKernelChoice::BipartiteMerge => {
            let t = Instant::now();
            let ctx = ds.map(|ds| {
                BipartiteCtx::with_settings(
                    ds,
                    cfg.metric,
                    panel_settings,
                    crate::runtime::xla_panel_dir(cfg),
                )
            });
            let (cache, phase_busy, anchors) = build_cache_pooled(
                ds,
                d,
                ctx.as_ref(),
                &plan,
                n_workers,
                cfg,
                net,
                affinity.as_ref(),
                holders.as_deref(),
                &residents,
                remote,
                &fleet,
                &witness,
                obs_run,
                &hub.local,
            )?;
            builders = anchors;
            for (w, b) in phase_busy.into_iter().enumerate() {
                metrics.worker_busy[w] += b;
            }
            metrics.phase_local_mst = t.elapsed();
            Some((ctx, cache))
        }
    };

    // The peer data plane: when routing is on (sharded default, or
    // `--peer-route`) and phase 1 cached trees at worker anchors, pair
    // scatter replaces inline tree sections with zero-payload routing
    // directives and the executing worker pulls the tree from its anchor.
    let route: Option<RouteCtx<'_>> = if cfg.effective_peer_route() && bip.is_some() {
        let ports: Vec<u16> = match remote {
            Some(tcp) => tcp.peer_addrs().iter().map(|a| a.port).collect(),
            // simulated peers always "listen": the fetch is a byte model
            None => vec![1; n_workers],
        };
        Some(RouteCtx {
            builders: builders.clone(),
            ports,
            no_route: (0..p).map(|_| AtomicBool::new(false)).collect(),
            links: Mutex::new(HashSet::new()),
            model: remote.is_none(),
            fleet: &fleet,
        })
    } else {
        None
    };

    // Both halves of the leaderless data plane need the fleet's routing
    // table on the workers: peers[w] for fold ships and routed fetches,
    // builders[k] for the anchor of each cached tree. (Kept around: a
    // mid-run admission replays the book so folds can target the newcomer.)
    let book_builders: Vec<u16> =
        if builders.len() == p { builders.clone() } else { vec![FOLD_KEEP; p] };
    if route.is_some() || topology_mode {
        match remote {
            Some(tcp) => {
                let book = Message::PeerBook {
                    peers: tcp.peer_addrs(),
                    builders: book_builders.clone(),
                };
                for w in 0..n_workers {
                    if !fleet.dead[w].load(Ordering::SeqCst) {
                        // a dead link surfaces on the driver's next frame
                        let _ = tcp.send_to(w, &book, Direction::Control);
                    }
                }
            }
            None => {
                // model the identical broadcast: header + one address entry
                // per worker + one u16 builder id per subset, per link
                let bytes =
                    HEADER_BYTES + n_workers as u64 * PEER_ENTRY_BYTES + p as u64 * 2;
                for _ in 0..n_workers {
                    net.charge(bytes, Direction::Control);
                }
            }
        }
    }

    // Phase 2: pair jobs over the pool — per-worker affinity decks with
    // idle stealing (capability-confined claims on sharded runs), or the
    // shared LPT deal when affinity is off.
    let t_pairs = Instant::now();
    let queue = match (&affinity, caps) {
        (Some(aff), Some(caps)) => JobQueue::with_decks_capped(aff.decks.clone(), caps),
        (Some(aff), None) => JobQueue::with_decks(aff.decks.clone()),
        (None, _) => JobQueue::new(plan.lpt_order.clone()),
    };
    let (tx_leader, rx_leader) = channel::<Message>();
    let mut union_edges: Vec<Edge> = Vec::new();
    let mut worker_trees: Vec<Vec<Edge>> = Vec::new();
    // simulated tree/ring runs: partials gathered per worker, folded by the
    // modeled schedule after the gather loop
    let mut sim_partials: Vec<(usize, Vec<Edge>)> = Vec::new();
    let mut stream = if cfg.stream_reduce { Some(StreamReducer::new(n)) } else { None };
    let mut reduce_time = Duration::ZERO;
    let worker_errors: Mutex<Vec<String>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| -> anyhow::Result<()> {
        let plan_ref = &plan;
        let queue_ref = &queue;
        let bip_ref = bip.as_ref();
        let witness_ref = &witness;
        let route_ref = route.as_ref();
        let errors_ref = &worker_errors;
        let fleet_ref = &fleet;
        let hub_ref = &hub;
        let use_affinity = affinity.is_some();
        for (w, resident) in residents.iter().enumerate().take(n_workers) {
            let tx = tx_leader.clone();
            match remote {
                Some(tcp) => {
                    let cache = bip_ref.map(|(_, c)| c);
                    scope.spawn(move || {
                        if let Some(t) = obs_run {
                            crate::obs::adopt(t);
                        }
                        pooled_worker_remote(
                            w,
                            ds,
                            d,
                            plan_ref,
                            queue_ref,
                            cfg,
                            net,
                            tcp,
                            cache,
                            use_affinity,
                            resident,
                            witness_ref,
                            route_ref,
                            fleet_ref,
                            errors_ref,
                            tx,
                        )
                    });
                }
                None => {
                    let ds = ds.expect("in-process execution holds the dataset");
                    scope.spawn(move || {
                        if let Some(t) = obs_run {
                            crate::obs::adopt(t);
                        }
                        pooled_worker_local(
                            w,
                            ds,
                            plan_ref,
                            queue_ref,
                            cfg,
                            net,
                            bip_ref,
                            use_affinity,
                            resident,
                            witness_ref,
                            route_ref,
                            sim_topology,
                            errors_ref,
                            &hub_ref.local,
                            tx,
                        )
                    });
                }
            }
        }
        // Remote runs keep one sender for drivers spawned on mid-run
        // admission; the channel then drains on the done count alone.
        let tx_admit: Option<Sender<Message>> = remote.map(|_| tx_leader.clone());
        drop(tx_leader); // leader keeps only rx (plus the admission spare)

        // Remote workers report the panel ISA they actually dispatched;
        // collected here and summarized once the fleet has drained, so a
        // late frame cannot leave a first-writer's label standing.
        let mut fleet_isas: Vec<u8> = Vec::new();
        // Live ticker: tty-gated inside, `--quiet` turns the config side
        // off. One `\r` line, redrawn at most every 100 ms.
        let mut progress = crate::obs::Progress::new(plan_ref.n_jobs(), cfg.obs.progress);
        let mut done = 0usize;
        let mut expected_done = n_workers;
        // links already driven: everything below this index has a driver
        let mut activated = n_workers;
        while done < expected_done {
            // Remote elastic runs poll: a newly admitted link must be
            // activated even while every running driver is mid-job.
            let msg = if remote.is_some() {
                match rx_leader.recv_timeout(ADMIT_POLL) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        unreachable!("drivers outstanding but all senders hung up")
                    }
                }
            } else {
                Some(rx_leader.recv().expect("all workers hung up"))
            };
            // Activate links the admission thread appended since last look:
            // give the newcomer a (rebalanced) deck, revive its fleet slot,
            // replay the routing book, and spawn its driver. Admission is
            // pure scheduling — the job set and ⊕-reduction are unchanged,
            // so the final tree stays bit-identical.
            if let Some(tcp) = remote {
                while activated < tcp.len().min(fleet.dead.len()) {
                    let w = activated;
                    activated += 1;
                    let caps_row: Option<Vec<bool>> = holders.as_ref().map(|_| {
                        let mut held = vec![false; p];
                        for k in tcp.advertised(w) {
                            if (k as usize) < p {
                                held[k as usize] = true;
                            }
                        }
                        plan_ref
                            .jobs
                            .iter()
                            .map(|job| held[job.i as usize] && held[job.j as usize])
                            .collect()
                    });
                    if sharded {
                        let mut res = residents[w].lock().unwrap();
                        for k in tcp.advertised(w) {
                            if (k as usize) < p {
                                res[k as usize].vecs = true;
                            }
                        }
                    }
                    if use_affinity {
                        let deck = queue_ref.admit_worker(caps_row);
                        debug_assert_eq!(deck, w, "deck index must track link index");
                    }
                    if metrics.worker_busy.len() <= w {
                        metrics.worker_busy.resize(w + 1, Duration::ZERO);
                    }
                    // Revive the spare slot. `dead` flips last: once a peer
                    // can observe the worker alive, its deck and caps row
                    // are already in place.
                    fleet.fold_jobs[w].lock().unwrap().clear();
                    fleet.fold_expect[w].store(0, Ordering::SeqCst);
                    fleet.finished[w].store(false, Ordering::SeqCst);
                    fleet.dead[w].store(false, Ordering::SeqCst);
                    metrics.workers_admitted += 1;
                    if route_ref.is_some() || topology_mode {
                        let book = Message::PeerBook {
                            peers: tcp.peer_addrs(),
                            builders: book_builders.clone(),
                        };
                        for v in 0..tcp.len().min(fleet.dead.len()) {
                            if !fleet.dead[v].load(Ordering::SeqCst) {
                                let _ = tcp.send_to(v, &book, Direction::Control);
                            }
                        }
                    }
                    expected_done += 1;
                    let tx = tx_admit.clone().expect("remote run holds the admission sender");
                    let resident = &residents[w];
                    let cache = bip_ref.map(|(_, c)| c);
                    crate::obs::log!(
                        info,
                        "leader: worker {w} admitted mid-run; rebalancing onto it"
                    );
                    crate::obs::instant(
                        crate::obs::SpanKind::Admit,
                        crate::obs::trace::LEADER_TRACK,
                        w as u32,
                        w as u64,
                    );
                    scope.spawn(move || {
                        if let Some(t) = obs_run {
                            crate::obs::adopt(t);
                        }
                        pooled_worker_remote(
                            w,
                            ds,
                            d,
                            plan_ref,
                            queue_ref,
                            cfg,
                            net,
                            tcp,
                            cache,
                            use_affinity,
                            resident,
                            witness_ref,
                            route_ref,
                            fleet_ref,
                            errors_ref,
                            tx,
                        )
                    });
                }
            }
            {
                // Live queue depth for the exposition endpoint: jobs not
                // yet durably gathered (relaxed store, one per message).
                let done_jobs = if remote.is_some() {
                    fleet.done_jobs.load(Ordering::SeqCst)
                } else {
                    metrics.jobs as usize
                };
                hub.local.gauge_set(
                    crate::obs::metrics::Gauge::QueueDepth,
                    plan_ref.n_jobs().saturating_sub(done_jobs) as i64,
                );
            }
            if progress.active() {
                let done_jobs = if remote.is_some() {
                    fleet.done_jobs.load(Ordering::SeqCst)
                } else {
                    metrics.jobs as usize
                };
                progress.tick(
                    done_jobs,
                    counters.snapshot().1,
                    fleet.stalls.load(Ordering::Relaxed),
                    metrics.workers_admitted,
                );
            }
            let Some(msg) = msg else { continue };
            match msg {
                Message::Result { job_id, worker, edges, compute } => {
                    if remote.is_some() && obs_run.is_some() {
                        // Evidence for span synthesis: if this job's runner
                        // dies before its rendezvous, the span block never
                        // arrives and this row becomes the job's timeline.
                        job_log.push((
                            job_id,
                            worker as u16,
                            compute.as_nanos() as u64,
                            crate::obs::now_ns(),
                        ));
                    }
                    metrics.jobs += 1;
                    metrics.job_times.push(compute);
                    metrics.union_edges += edges.len();
                    if let Some(r) = &mut stream {
                        let t = Instant::now();
                        r.push(&edges);
                        reduce_time += t.elapsed();
                    } else {
                        union_edges.extend_from_slice(&edges);
                    }
                }
                Message::WorkerDone {
                    worker,
                    local_tree,
                    dist_evals,
                    busy,
                    jobs_run,
                    jobs_stolen,
                    panel_hits,
                    panel_misses,
                    panel_flops,
                    panel_time,
                    panel_threads,
                    panel_isa,
                    peer_tx_bytes,
                    peer_ships,
                    spans,
                    now_ns,
                    chaos_faults,
                    metrics: worker_metrics,
                } => {
                    if let Some(snap) = worker_metrics {
                        // Final cumulative snapshot — replaces any periodic
                        // push this worker made (latest-wins by design).
                        hub.absorb(worker as u16, snap);
                    }
                    metrics.chaos_faults_injected += u64::from(chaos_faults);
                    if !spans.is_empty() {
                        // Re-base the worker process's monotonic clock onto
                        // the leader's: the worker stamped `now_ns` as it
                        // sent, so receive-time minus that is the offset
                        // (inflated by one-way latency — fine for a trace).
                        let offset = if now_ns == 0 {
                            0
                        } else {
                            i128::from(crate::obs::now_ns()) - i128::from(now_ns)
                        };
                        for mut s in spans {
                            s.start_ns = (i128::from(s.start_ns) + offset).max(0) as u64;
                            s.end_ns = (i128::from(s.end_ns) + offset).max(0) as u64;
                            metrics.spans.push(s);
                        }
                    }
                    metrics.dist_evals += dist_evals;
                    // += : the local-MST phase already deposited its share
                    metrics.worker_busy[worker] += busy;
                    metrics.jobs_stolen += jobs_stolen;
                    metrics.panel_hits += panel_hits;
                    metrics.panel_misses += panel_misses;
                    metrics.panel_flops += panel_flops;
                    metrics.panel_time += panel_time;
                    metrics.panel_threads_used = metrics.panel_threads_used.max(panel_threads);
                    metrics.peer_bytes += peer_tx_bytes;
                    metrics.peer_ships += peer_ships;
                    if remote.is_some() && panel_isa != 0 {
                        fleet_isas.push(panel_isa);
                    }
                    if cfg.reduce_tree {
                        metrics.jobs += jobs_run;
                    }
                    if let Some(t) = local_tree {
                        if sim_topology {
                            sim_partials.push((worker, t));
                        } else {
                            metrics.union_edges += t.len();
                            if let Some(r) = &mut stream {
                                let t0 = Instant::now();
                                r.push(&t);
                                reduce_time += t0.elapsed();
                            } else {
                                worker_trees.push(t);
                            }
                        }
                    }
                    done += 1;
                }
                other => anyhow::bail!("leader received unexpected message {other:?}"),
            }
        }
        progress.finish();
        if remote.is_some() {
            // Pure-remote runs: the `kernel:` line must describe the fleet,
            // not the leader's local ISA detection (the leader ran no
            // panels). One ISA across the fleet replaces the leader's
            // label; disagreeing fleets get an explicit mixed summary.
            fleet_isas.sort_unstable();
            fleet_isas.dedup();
            let decoded: Vec<Isa> =
                fleet_isas.iter().filter_map(|&c| Isa::from_wire_code(c)).collect();
            match decoded.as_slice() {
                [] => {} // no remote worker ran a panel
                [one] => {
                    metrics.panel_isa = one.label().to_string();
                    metrics.panel_lanes = one.lanes() as u32;
                    // the leader-local fallback note does not describe the
                    // fleet that actually dispatched
                    metrics.panel_fallback = None;
                }
                many => {
                    let labels: Vec<&str> = many.iter().map(|i| i.label()).collect();
                    metrics.panel_isa = format!("mixed[{}]", labels.join(","));
                    metrics.panel_lanes =
                        many.iter().map(|i| i.lanes() as u32).min().unwrap_or(0);
                    metrics.panel_fallback = Some(
                        "remote workers dispatched different panel ISAs; per-fleet split above"
                            .to_string(),
                    );
                }
            }
        }
        Ok(())
    })?;

    let worker_errors = worker_errors.into_inner().unwrap();
    if !worker_errors.is_empty() {
        anyhow::bail!("distributed run failed: {}", worker_errors.join("; "));
    }
    metrics.worker_failures = fleet.failures.load(Ordering::Relaxed);
    metrics.jobs_reassigned = fleet.reassigned.load(Ordering::Relaxed);
    metrics.stalls_detected = fleet.stalls.load(Ordering::Relaxed);
    // Jobs re-run after a fold failure were already reported once by their
    // original (settled) runner; the audit counts each job exactly once.
    metrics.jobs = metrics
        .jobs
        .saturating_sub(fleet.fold_rerun_credit.load(Ordering::Relaxed));
    let expected_jobs = plan.n_jobs() as u32;
    if metrics.jobs != expected_jobs {
        anyhow::bail!(
            "job count mismatch: expected {expected_jobs}, completed {} ({} worker link(s) failed; the surviving fleet could not finish the deck)",
            metrics.jobs,
            metrics.worker_failures
        );
    }
    // Streaming folds ran inside the gather loop; carve them out of the
    // pair phase so the three phases stay (approximately) additive.
    metrics.phase_pair = t_pairs.elapsed().saturating_sub(reduce_time);

    // Simulated tree/ring reduction: fold the workers' partials along the
    // modeled schedule. The result is the reduction output and its byte
    // charges are the model — identical in shape to what the real drivers
    // measure on a TCP run.
    if sim_topology {
        let root_tree = model_fold_topology(
            n,
            topology,
            n_workers,
            std::mem::take(&mut sim_partials),
            net,
            &counters,
            route.as_ref(),
        );
        metrics.union_edges += root_tree.len();
        if let Some(r) = &mut stream {
            let t0 = Instant::now();
            r.push(&root_tree);
            reduce_time += t0.elapsed();
        } else {
            worker_trees.push(root_tree);
        }
    }

    // Final reduction. (Perf note inherited from the pre-exec leader:
    // deduplicating (u,v) pairs before the batch Kruskal was tried and
    // reverted — dedup itself sorts the full union, so it only adds work.)
    let t_mst = Instant::now();
    let mst = if let Some(r) = stream {
        metrics.reduce_folds = r.merges as u32;
        metrics.reduce_fold_edges = r.fold_edges;
        r.finish()
    } else if cfg.reduce_tree {
        // reduction runs at the leader; NetSim already charged each worker
        // tree's gather, so the final hop must not be counted again
        let (tree, _stats) = reduce_trees_with(n, &worker_trees, false);
        tree
    } else {
        kruskal(n, &union_edges)
    };
    metrics.final_mst = t_mst.elapsed();
    metrics.phase_reduce = reduce_time + metrics.final_mst;
    // The leader's final reduction is a fold like any other for the
    // fleet-wide fold-latency histogram.
    hub.local.observe(
        crate::obs::metrics::Hist::Fold,
        u64::try_from(metrics.final_mst.as_nanos()).unwrap_or(u64::MAX),
    );
    hub.local.gauge_set(crate::obs::metrics::Gauge::QueueDepth, 0);
    metrics.scatter_saved_bytes = witness.saved.load(Ordering::Relaxed);
    metrics.leader_ingest_bytes = witness.ingest.load(Ordering::Relaxed);
    metrics.leader_data_bytes = witness.data.load(Ordering::Relaxed);

    metrics.pair_evals = metrics.dist_evals;
    if let Some((_, cache)) = &bip {
        metrics.local_mst_evals = cache.evals;
        metrics.dist_evals += cache.evals;
    }

    let (s, g, c, m) = counters.snapshot();
    metrics.scatter_bytes = s;
    metrics.gather_bytes = g;
    metrics.control_bytes = c;
    metrics.messages = m;
    // Leader-link split: every leader byte is either data-plane payload
    // (vectors + inline trees beyond frame headers) or control (headers,
    // directives, gathered results). Peer traffic never crosses the leader
    // — measured worker-side on TCP (summed from `WorkerDone`), modeled
    // into the peer counter on the simulated fabric.
    metrics.leader_control_bytes = (s + g + c).saturating_sub(metrics.leader_data_bytes);
    metrics.peer_bytes += counters.peer();
    metrics.reduce_topology = cfg.reduce_topology.name().to_string();
    metrics.peer_route = route.is_some();
    // The leaderless-data-plane invariant: on a sharded routed run the
    // leader never sources payload — vectors are worker-resident and every
    // cached tree travels worker↔worker. Only a degraded route (a failed
    // fetch demoting a subset to inline shipping) may break this.
    if sharded {
        if let Some(rc) = &route {
            let demoted = rc.no_route.iter().any(|f| f.load(Ordering::Relaxed));
            anyhow::ensure!(
                demoted || metrics.leader_data_bytes == 0,
                "sharded peer-routed run leaked {} payload bytes through the leader",
                metrics.leader_data_bytes
            );
        }
    }
    if let Some(token) = obs_run {
        // Leader + in-process spans drain straight into the timeline (the
        // shipped worker spans were re-based and collected in the gather
        // loop above).
        metrics.spans.extend(crate::obs::end_run(token));
        // A runner that died before its shutdown rendezvous never shipped
        // its span block; its delivered jobs still have gather-log rows, so
        // synthesize their Job spans (arg = 0: the eval counts died with
        // the worker) — the trace must cover every executed pair job.
        let shipped: std::collections::HashSet<u32> = metrics
            .spans
            .iter()
            .filter(|s| s.kind() == Some(crate::obs::SpanKind::Job))
            .map(|s| s.id)
            .collect();
        for (job_id, worker, compute_ns, recv_ns) in job_log {
            if !shipped.contains(&job_id) {
                metrics.spans.push(crate::obs::Span {
                    kind_code: crate::obs::SpanKind::Job.code(),
                    worker,
                    id: job_id,
                    arg: 0,
                    start_ns: recv_ns.saturating_sub(compute_ns),
                    end_ns: recv_ns,
                });
            }
        }
    }
    // The printed roster must be the fleet that finished the run: a worker
    // admitted mid-run whose busy slot never got touched would otherwise be
    // silently missing from the per-worker busy% lines.
    metrics.finalize_roster(n_workers);
    metrics.wall = t_start.elapsed();
    // One last merge so the report/summary see the final state, then stop
    // the exposition listener (it served live merges throughout the run).
    metrics.fleet_metrics = Some(hub.merged());
    metrics.metrics_workers_reporting = hub.workers_reporting() as u32;
    if let Some(srv) = metrics_server {
        srv.stop();
    }

    Ok(PooledRun { mst, metrics, workers: n_workers })
}

/// One in-process pooled worker: claim jobs until the decks drain (own
/// deck first, then stealing), charging the scatter for each claimed job —
/// under the resident-set model only the payload this worker does not yet
/// hold — and shipping each pair tree (or a locally ⊕-combined tree) back
/// to the leader. In-process solvers share the leader's memory; the charge
/// is the byte *model* of what the wire encoding would occupy.
fn pooled_worker_local(
    worker_id: usize,
    ds: &Dataset,
    plan: &ExecPlan,
    queue: &JobQueue,
    cfg: &RunConfig,
    net: &dyn Transport,
    bip: Option<&(Option<BipartiteCtx>, LocalMstCache)>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    witness: &ByteWitness,
    route: Option<&RouteCtx<'_>>,
    bare_done: bool,
    errors: &Mutex<Vec<String>>,
    reg: &crate::obs::metrics::Registry,
    tx_leader: Sender<Message>,
) {
    let cache = bip.map(|(_, c)| c);
    let mut solver: Box<dyn PairSolver + '_> = match bip {
        Some((ctx, cache)) => {
            let ctx = ctx.as_ref().expect("in-process bipartite runs carry their context");
            Box::new(BipartitePairSolver::new(ds, ctx, cache))
        }
        None => match crate::coordinator::worker::build_kernel(cfg) {
            Ok(kernel) => Box::new(DensePairSolver::owned(ds, kernel)),
            Err(e) => {
                // Report failure as an empty done message; the leader
                // surfaces the recorded error after the gather loop.
                errors
                    .lock()
                    .unwrap()
                    .push(format!("worker {worker_id}: kernel init failed: {e:#}"));
                let _ = net.send(
                    &tx_leader,
                    Message::WorkerDone {
                        worker: worker_id,
                        local_tree: None,
                        dist_evals: 0,
                        busy: Duration::ZERO,
                        jobs_run: 0,
                        jobs_stolen: 0,
                        panel_hits: 0,
                        panel_misses: 0,
                        panel_flops: 0,
                        panel_time: Duration::ZERO,
                        panel_threads: 0,
                        panel_isa: 0,
                        peer_tx_bytes: 0,
                        peer_ships: 0,
                        spans: Vec::new(),
                        now_ns: 0,
                        chaos_faults: 0,
                        metrics: None,
                    },
                    Direction::Gather,
                );
                return;
            }
        },
    };
    let local_reduce = cfg.reduce_tree;
    let mut busy = Duration::ZERO;
    let mut jobs_run = 0u32;
    let mut jobs_stolen = 0u32;
    let mut local_tree: Option<Vec<Edge>> = None;
    while let Some((job_idx, stolen)) = queue.pop_for(worker_id) {
        let job = &plan.jobs[job_idx];
        let ship = charge_job_scatter(
            plan,
            job,
            ds.d,
            cache,
            use_affinity,
            resident,
            net,
            witness,
            route,
            worker_id,
        );
        if stolen {
            jobs_stolen += 1;
            reg.add(crate::obs::metrics::Ctr::JobsStolen, 1);
        }
        let evals_before = solver.dist_evals();
        let mut job_span = crate::obs::span(crate::obs::SpanKind::Job, worker_id as u16, job.id);
        let t = Instant::now();
        let solved = match solver.solve_shipped(plan, job, &ship) {
            Ok(s) => s,
            Err(e) => {
                errors
                    .lock()
                    .unwrap()
                    .push(format!("worker {worker_id}: pair job {} failed: {e:#}", job.id));
                break;
            }
        };
        let compute = solved.compute.unwrap_or_else(|| t.elapsed());
        let evals = solver.dist_evals() - evals_before;
        job_span.set_arg(evals);
        drop(job_span);
        reg.observe_job(u64::try_from(compute.as_nanos()).unwrap_or(u64::MAX), job.i, job.j);
        reg.add(crate::obs::metrics::Ctr::DistEvals, evals);
        busy += compute;
        jobs_run += 1;
        if local_reduce {
            let t2 = Instant::now();
            local_tree = Some(match local_tree.take() {
                None => solved.edges,
                Some(prev) => tree_merge(ds.n, &prev, &solved.edges),
            });
            let fold_dt = t2.elapsed();
            reg.observe(
                crate::obs::metrics::Hist::Fold,
                u64::try_from(fold_dt.as_nanos()).unwrap_or(u64::MAX),
            );
            busy += fold_dt;
        } else if net
            .send(
                &tx_leader,
                Message::Result {
                    job_id: job.id,
                    worker: worker_id,
                    edges: solved.edges,
                    compute,
                },
                Direction::Gather,
            )
            .is_err()
        {
            return; // leader gone
        }
    }
    // Queue drained (or aborted): model the shutdown control message, then
    // drain the solver and report.
    net.charge(HEADER_BYTES, Direction::Control);
    let fin = match solver.finish() {
        Ok(f) => f,
        Err(e) => {
            errors
                .lock()
                .unwrap()
                .push(format!("worker {worker_id}: shutdown rendezvous failed: {e:#}"));
            SolverFinal::default()
        }
    };
    let done = Message::WorkerDone {
        worker: worker_id,
        local_tree: fin.local_tree.or(local_tree),
        dist_evals: fin.dist_evals,
        busy: fin.busy.unwrap_or(busy),
        jobs_run,
        jobs_stolen,
        panel_hits: fin.panel_hits,
        panel_misses: fin.panel_misses,
        panel_flops: fin.panel_perf.flops,
        panel_time: fin.panel_perf.time,
        panel_threads: fin.panel_perf.threads,
        panel_isa: fin.panel_perf.isa,
        peer_tx_bytes: 0,
        peer_ships: 0,
        // In-process spans never ride the channel: the leader drains the
        // shared recorder directly at run end (same process, same clock).
        spans: Vec::new(),
        now_ns: 0,
        chaos_faults: 0,
        // In-process metrics never ride the channel either: this thread
        // recorded straight into the run hub's local registry, and a None
        // block keeps the simulated byte model identical to an unarmed
        // TCP frame.
        metrics: None,
    };
    if bare_done {
        // Tree/ring model: this partial ships over a *peer* hop, not the
        // leader link — charge the frame as if it carried no tree (the
        // root's folded payload is charged by the schedule model instead).
        net.charge(HEADER_BYTES + crate::net::wire::STATS_BYTES, Direction::Gather);
        let _ = tx_leader.send(done);
    } else {
        let _ = net.send(&tx_leader, done, Direction::Gather);
    }
}

/// Mutable state of one remote link's drive loop, shared with the failure
/// handler so a dead link can return exactly the jobs it lost.
struct RemoteDrive {
    /// claimed job indices whose replies have not arrived (FIFO)
    inflight: VecDeque<usize>,
    /// reduce mode: jobs folded into the worker's never-yet-gathered tree
    acked: Vec<usize>,
    /// jobs whose results were durably recorded at the leader
    delivered: u32,
    jobs_stolen: u32,
    busy: Duration,
    /// tree/ring topologies: this link's fold directive has been issued
    /// (successful or degraded) — never fold twice
    fold_done: bool,
    fin: Option<SolverFinal>,
}

/// One remote pooled worker: drive worker `w`'s link with a bounded
/// in-flight window, and on link death hand every undelivered job back to
/// the queue's return lane for the surviving fleet.
fn pooled_worker_remote(
    worker_id: usize,
    ds: Option<&Dataset>,
    d: usize,
    plan: &ExecPlan,
    queue: &JobQueue,
    cfg: &RunConfig,
    net: &dyn Transport,
    tcp: &TcpTransport,
    cache: Option<&LocalMstCache>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    witness: &ByteWitness,
    route: Option<&RouteCtx<'_>>,
    fleet: &Fleet,
    errors: &Mutex<Vec<String>>,
    tx_leader: Sender<Message>,
) {
    let mut st = RemoteDrive {
        inflight: VecDeque::new(),
        acked: Vec::new(),
        delivered: 0,
        jobs_stolen: 0,
        busy: Duration::ZERO,
        fold_done: false,
        fin: None,
    };
    let outcome = if fleet.dead[worker_id].load(Ordering::SeqCst) {
        // Link already died in phase 1: free this deck for the survivors
        // (nothing was claimed, so nothing counts as reassigned).
        queue.abandon_deck(worker_id);
        Ok(())
    } else {
        let link = RemoteLink::new(tcp, worker_id, ds, cache, cfg.reduce_tree);
        drive_remote_link(
            worker_id,
            &link,
            plan,
            queue,
            cfg,
            net,
            cache,
            d,
            use_affinity,
            resident,
            witness,
            route,
            fleet,
            errors,
            &tx_leader,
            &mut st,
        )
    };
    let (jobs_run, fin) = match outcome {
        Ok(()) => {
            let fin = st.fin.take().unwrap_or_default();
            (st.delivered, fin)
        }
        Err(e) => {
            // Everything claimed but not durably recorded goes back: the
            // in-flight window, plus (reduce mode) every job whose result
            // lives only in the worker's never-gathered local fold, plus
            // any jobs this worker **inherited** through completed ⊕-fold
            // hops — their results live in the partial that died with it.
            // Re-running an inherited job is harmless (⊕ is idempotent),
            // and the surviving fold chain absorbs the duplicate edges.
            // The dead flag is stored LAST — a peer that observes it must
            // already be able to see the rolled-back done count and the
            // returned jobs, or it could disperse mid-failover.
            let refolded = st.acked.len();
            let mut lost: Vec<usize> = st.inflight.drain(..).collect();
            lost.append(&mut st.acked);
            let mut inherited: Vec<usize> =
                fleet.fold_jobs[worker_id].lock().unwrap().drain(..).collect();
            fleet.done_jobs.fetch_sub(refolded + inherited.len(), Ordering::SeqCst);
            fleet
                .fold_rerun_credit
                .fetch_add(inherited.len() as u32, Ordering::Relaxed);
            lost.append(&mut inherited);
            fleet.reassigned.fetch_add(lost.len() as u32, Ordering::Relaxed);
            queue.push_returned(&lost);
            queue.abandon_deck(worker_id);
            // A tripped liveness deadline is a *stall*, not a dead socket —
            // counted separately, demoted identically.
            let stalled = crate::net::is_stall(&e);
            if stalled {
                fleet.stalls.fetch_add(1, Ordering::Relaxed);
            }
            fleet.fail_worker(worker_id);
            if stalled {
                crate::obs::instant(
                    crate::obs::SpanKind::Stall,
                    crate::obs::trace::LEADER_TRACK,
                    worker_id as u32,
                    0,
                );
            }
            crate::obs::instant(
                crate::obs::SpanKind::Failover,
                crate::obs::trace::LEADER_TRACK,
                worker_id as u32,
                lost.len() as u64,
            );
            crate::obs::log!(
                warn,
                "leader: worker {worker_id} link {} mid-run ({e:#}); returned {} job(s) to the deck",
                if stalled { "stalled" } else { "failed" },
                lost.len()
            );
            (st.delivered - refolded as u32, SolverFinal::default())
        }
    };
    let _ = net.send(
        &tx_leader,
        Message::WorkerDone {
            worker: worker_id,
            local_tree: fin.local_tree,
            dist_evals: fin.dist_evals,
            busy: fin.busy.unwrap_or(st.busy),
            jobs_run,
            jobs_stolen: st.jobs_stolen,
            panel_hits: fin.panel_hits,
            panel_misses: fin.panel_misses,
            panel_flops: fin.panel_perf.flops,
            panel_time: fin.panel_perf.time,
            panel_threads: fin.panel_perf.threads,
            panel_isa: fin.panel_perf.isa,
            peer_tx_bytes: fin.peer_tx_bytes,
            peer_ships: fin.peer_ships,
            // The worker process's span block (and its send-time clock)
            // rides through unchanged; the gather loop re-bases it.
            spans: fin.spans,
            now_ns: fin.now_ns,
            chaos_faults: fin.chaos_faults,
            metrics: fin.metrics,
        },
        Direction::Gather,
    );
}

/// The windowed drive loop of one healthy link. Returns `Err` on a link
/// failure (socket death, protocol violation) — the caller turns the
/// undelivered state into return-lane entries.
fn drive_remote_link(
    worker_id: usize,
    link: &RemoteLink<'_>,
    plan: &ExecPlan,
    queue: &JobQueue,
    cfg: &RunConfig,
    net: &dyn Transport,
    cache: Option<&LocalMstCache>,
    d: usize,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    witness: &ByteWitness,
    route: Option<&RouteCtx<'_>>,
    fleet: &Fleet,
    errors: &Mutex<Vec<String>>,
    tx_leader: &Sender<Message>,
    st: &mut RemoteDrive,
) -> anyhow::Result<()> {
    let window = cfg.pipeline_window.max(1);
    let topology_mode = cfg.reduce_tree && cfg.reduce_topology != ReduceTopology::Leader;
    loop {
        // Top up the in-flight window: send the next claimed job before
        // awaiting the previous reply — scatter overlaps remote compute.
        while st.inflight.len() < window {
            let Some((job_idx, stolen)) = queue.pop_for(worker_id) else { break };
            let job = &plan.jobs[job_idx];
            let planned =
                plan_job_scatter(plan, job, d, cache, use_affinity, resident, route, worker_id);
            if stolen {
                st.jobs_stolen += 1;
            }
            // Push before sending: a failed send means the job is lost in
            // flight and must be returned.
            st.inflight.push_back(job_idx);
            link.send_pair(plan, job, &planned.ship)?;
            // Counters only after the frame left: a failed send returns
            // the job unaccounted, and the survivor's re-send is the one
            // (and only) transfer the witnesses record.
            account_job_scatter(&planned, net, witness, route, worker_id);
        }
        if st.inflight.is_empty() {
            if fleet.complete() || fleet.aborted() {
                // Reduce mode: an acked fold is durable only once its
                // worker's shutdown rendezvous gathers the folded tree, so
                // shut links down in worker-id order — if a lower-id
                // peer's rendezvous fails, its acked jobs return to the
                // lane, `complete()` flips back off, and this still-open
                // link picks them up instead of having already dispersed.
                // (Only a failure at the very last live rendezvous has no
                // fleet left to recover on; that aborts loudly with a job
                // count mismatch — exactness is never at risk.)
                if cfg.reduce_tree && !fleet.aborted() {
                    let lower_all_settled = (0..worker_id).all(|v| {
                        fleet.dead[v].load(Ordering::SeqCst)
                            || fleet.finished[v].load(Ordering::SeqCst)
                    });
                    if !lower_all_settled {
                        std::thread::sleep(Duration::from_millis(1));
                        continue;
                    }
                    // Settled observed — now re-read completion. A failing
                    // peer rolls its done count back *before* raising its
                    // dead flag, so a completion glimpsed before that
                    // rollback is caught here and the loop resumes
                    // claiming the returned jobs instead of dispersing.
                    if !fleet.complete() {
                        continue;
                    }
                    // Tree/ring topologies: before dispersing, drive this
                    // worker's one ⊕-fold hop. Lower ids have all settled
                    // (gate above), so every partial destined for this
                    // worker is already in its inbox or on the wire.
                    if topology_mode && !st.fold_done {
                        st.fold_done = true;
                        let target =
                            fold_target(cfg.reduce_topology, &fleet.alive(), worker_id);
                        let expect =
                            fleet.fold_expect[worker_id].load(Ordering::SeqCst) as u16;
                        let ok = link.fold(target, expect)?;
                        if ok {
                            // Job ownership follows the partial: everything
                            // this worker folded (its own acks plus any
                            // inherited bags) now lives in the shipped
                            // partial at `target` — or stays here when this
                            // worker is the schedule's root.
                            let mut moved: Vec<usize> = st.acked.drain(..).collect();
                            if target == FOLD_KEEP {
                                fleet.fold_jobs[worker_id]
                                    .lock()
                                    .unwrap()
                                    .append(&mut moved);
                            } else {
                                let mut bag: Vec<usize> = fleet.fold_jobs[worker_id]
                                    .lock()
                                    .unwrap()
                                    .drain(..)
                                    .collect();
                                moved.append(&mut bag);
                                fleet.fold_jobs[target as usize]
                                    .lock()
                                    .unwrap()
                                    .append(&mut moved);
                                fleet.fold_expect[target as usize]
                                    .fetch_add(1, Ordering::SeqCst);
                            }
                        } else {
                            // Degraded fold: a peer partial never arrived.
                            // The worker kept its own partial (own acks
                            // stay durable through its rendezvous), but the
                            // inherited jobs' results lived only in the
                            // missing partial — return them to the
                            // exactly-once lane; ⊕'s idempotence makes the
                            // duplicate re-runs harmless.
                            let returned: Vec<usize> = fleet.fold_jobs[worker_id]
                                .lock()
                                .unwrap()
                                .drain(..)
                                .collect();
                            if !returned.is_empty() {
                                fleet
                                    .done_jobs
                                    .fetch_sub(returned.len(), Ordering::SeqCst);
                                fleet
                                    .reassigned
                                    .fetch_add(returned.len() as u32, Ordering::Relaxed);
                                fleet
                                    .fold_rerun_credit
                                    .fetch_add(returned.len() as u32, Ordering::Relaxed);
                                queue.push_returned(&returned);
                                crate::obs::instant(
                                    crate::obs::SpanKind::Failover,
                                    crate::obs::trace::LEADER_TRACK,
                                    worker_id as u32,
                                    returned.len() as u64,
                                );
                                crate::obs::log!(
                                    warn,
                                    "leader: worker {worker_id} fold degraded (peer partial missing); returned {} inherited job(s) to the deck",
                                    returned.len()
                                );
                                continue;
                            }
                        }
                    }
                }
                break;
            }
            // Idle but the run is not done: a peer may yet fail and return
            // jobs this worker can run. Fail fast if returned work can no
            // longer run anywhere. A worker that already settled never
            // claims again, so only *available* (alive and unfinished)
            // workers count as capable here.
            if let Some(job_idx) = queue.stranded_job(&fleet.available()) {
                errors.lock().unwrap().push(format!(
                    "pair job {} lost: every worker capable of running it has failed",
                    plan.jobs[job_idx].id
                ));
                fleet.abort.store(true, Ordering::SeqCst);
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        // Await the oldest in-flight reply (frames are FIFO per link).
        let front_idx = *st.inflight.front().expect("checked non-empty");
        let job = &plan.jobs[front_idx];
        let solved = match link.recv_pair_reply(job)? {
            Some(s) => s,
            None => {
                // PairFail: the routed tree fetch fell through (builder died
                // between planning and fetch). The job never ran. Demote both
                // parts to inline shipping and return the job to the deck.
                st.inflight.pop_front();
                if let Some(rc) = route {
                    rc.no_route[job.i as usize].store(true, Ordering::Relaxed);
                    if job.j != job.i {
                        rc.no_route[job.j as usize].store(true, Ordering::Relaxed);
                    }
                }
                {
                    let mut res = resident.lock().unwrap();
                    res[job.i as usize].tree = false;
                    res[job.j as usize].tree = false;
                }
                fleet.reassigned.fetch_add(1, Ordering::Relaxed);
                queue.push_returned(&[front_idx]);
                continue;
            }
        };
        st.inflight.pop_front();
        st.delivered += 1;
        fleet.done_jobs.fetch_add(1, Ordering::SeqCst);
        if cfg.reduce_tree {
            // folded remotely; only durable once the final tree is gathered
            st.acked.push(front_idx);
        } else {
            let compute = solved.compute.unwrap_or_default();
            st.busy += compute;
            if tx_leader
                .send(Message::Result {
                    job_id: job.id,
                    worker: worker_id,
                    edges: solved.edges,
                    compute,
                })
                .is_err()
            {
                anyhow::bail!("leader gather channel closed");
            }
        }
    }
    // Drained (or aborted with an empty window): shutdown rendezvous —
    // collects the worker's stats and, in reduce mode, its ⊕-folded tree.
    st.fin = Some(link.finish()?);
    // Folds gathered: peers waiting on the ordered shutdown may proceed.
    fleet.finished[worker_id].store(true, Ordering::SeqCst);
    Ok(())
}

/// One planned pair-job scatter: the shipment decision plus the byte
/// quantities its accounting needs. Splitting *planning* (claim time, under
/// the residency lock) from *accounting* (after the frame actually leaves)
/// keeps the witness counters honest on elastic runs: a job whose send
/// fails is returned and re-planned by a survivor, and only payload that
/// really traveled is ever counted.
struct PlannedScatter {
    ship: Shipment,
    bytes: u64,
    dense_bytes: u64,
    vector_bytes: u64,
    /// trees demoted from inline shipping to a peer-plane fetch:
    /// `(building anchor, cached tree edge count)` per routed section
    routed: Vec<(usize, u64)>,
}

/// Decide one claimed job's shipment under the configured byte model and
/// mark the claimed sections held (no counters touched yet). With a
/// [`RouteCtx`], any inline tree whose building anchor is routable is
/// demoted to a zero-payload routed section — the executing worker pulls
/// it over a peer link instead, and the leader's scatter bytes drop by the
/// full tree payload (which `scatter_saved_bytes` then picks up, since the
/// dense baseline is unchanged).
fn plan_job_scatter(
    plan: &ExecPlan,
    job: &PairJob,
    d: usize,
    cache: Option<&LocalMstCache>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    route: Option<&RouteCtx<'_>>,
    worker_id: usize,
) -> PlannedScatter {
    let full = dense_shipment(job, cache.is_some());
    let dense_bytes = shipment_bytes(plan, job, d, cache, &full);
    let mut ship = if use_affinity {
        let mut res = resident.lock().unwrap();
        residual_shipment(job, cache.is_some(), res.as_mut_slice())
    } else {
        full
    };
    let mut routed = Vec::new();
    if let (Some(rc), Some(cache)) = (route, cache) {
        let _ = worker_id; // the executor side of every routed link
        if ship.tree_i && rc.routable(job.i as usize) {
            ship.tree_i = false;
            ship.route_i = true;
            routed.push((
                rc.builders[job.i as usize] as usize,
                cache.trees[job.i as usize].len() as u64,
            ));
        }
        if job.j != job.i && ship.tree_j && rc.routable(job.j as usize) {
            ship.tree_j = false;
            ship.route_j = true;
            routed.push((
                rc.builders[job.j as usize] as usize,
                cache.trees[job.j as usize].len() as u64,
            ));
        }
    }
    // After demotion: routed sections carry zero payload, so `bytes`
    // (and with it the scatter charge and `leader_data_bytes`) exclude
    // the tree, while `dense_bytes` still includes it.
    let bytes = shipment_bytes(plan, job, d, cache, &ship);
    let vector_bytes = ship_vector_bytes(plan, job, d, &ship);
    PlannedScatter { ship, bytes, dense_bytes, vector_bytes, routed }
}

/// Account one planned scatter that actually traveled (or, in-process, is
/// modeled as traveling): the transport charge, the bytes the resident-set
/// model avoided vs the dense ship-everything model, the vector-section
/// bytes that passed through the leader (`leader_ingest_bytes` — zero on
/// sharded runs by construction), the payload bytes beyond the frame
/// header (`leader_data_bytes`), and — on the simulated transport — the
/// modeled peer traffic of each routed fetch (`TreeFetch` + `TreeShip`
/// riding the executor→anchor link, `PeerHello` on first use; nothing for
/// a self-fetch, which the worker serves from its own cache).
fn account_job_scatter(
    planned: &PlannedScatter,
    net: &dyn Transport,
    witness: &ByteWitness,
    route: Option<&RouteCtx<'_>>,
    worker_id: usize,
) {
    net.charge(planned.bytes, Direction::Scatter);
    witness
        .saved
        .fetch_add(planned.dense_bytes - planned.bytes, Ordering::Relaxed);
    witness.ingest.fetch_add(planned.vector_bytes, Ordering::Relaxed);
    witness
        .data
        .fetch_add(planned.bytes.saturating_sub(HEADER_BYTES), Ordering::Relaxed);
    if let Some(rc) = route {
        for &(builder, edges) in &planned.routed {
            if builder == worker_id {
                continue; // self-fetch: served from the worker's own cache
            }
            let ship_bytes = 2 * HEADER_BYTES + edges * Edge::WIRE_BYTES as u64;
            rc.model_peer_hop(&net.counters(), worker_id, builder, ship_bytes);
        }
    }
}

/// Plan + account in one step — the in-process path, where the "transfer"
/// is the model itself and cannot fail.
#[allow(clippy::too_many_arguments)]
fn charge_job_scatter(
    plan: &ExecPlan,
    job: &PairJob,
    d: usize,
    cache: Option<&LocalMstCache>,
    use_affinity: bool,
    resident: &Mutex<Vec<Held>>,
    net: &dyn Transport,
    witness: &ByteWitness,
    route: Option<&RouteCtx<'_>>,
    worker_id: usize,
) -> Shipment {
    let planned =
        plan_job_scatter(plan, job, d, cache, use_affinity, resident, route, worker_id);
    account_job_scatter(&planned, net, witness, route, worker_id);
    planned.ship
}

/// The dense-model shipment: everything the job consumes travels, every
/// time. The degenerate self-pair job (`|P| = 1`) under the bipartite
/// kernel consumes only the cached tree (its vectors were already shipped
/// by the local-MST phase); under the dense kernel it consumes the
/// subset's vectors.
pub(crate) fn dense_shipment(job: &PairJob, has_cache: bool) -> Shipment {
    if job.i == job.j {
        if has_cache {
            Shipment { tree_i: true, ..Default::default() }
        } else {
            Shipment { vec_i: true, ..Default::default() }
        }
    } else {
        Shipment {
            vec_i: true,
            vec_j: true,
            tree_i: has_cache,
            tree_j: has_cache,
            ..Default::default()
        }
    }
}

/// The resident-set shipment: the same per-subset payload as
/// [`dense_shipment`], restricted to the sections the executing worker
/// does not already hold, which are marked held afterwards. Per job this
/// is ≤ the dense model by construction (the per-section terms are
/// identical), so total affinity scatter can never exceed the dense model.
/// On leader-resident runs vectors and trees toggle together, reproducing
/// the historical one-flag model; on sharded runs vectors are pre-held
/// everywhere they were advertised and only cached trees ever ship.
fn residual_shipment(job: &PairJob, has_cache: bool, held: &mut [Held]) -> Shipment {
    let (i, j) = (job.i as usize, job.j as usize);
    let mut ship = Shipment::default();
    if i == j {
        if has_cache {
            if !held[i].tree {
                held[i].tree = true;
                ship.tree_i = true;
            }
        } else if !held[i].vecs {
            held[i].vecs = true;
            ship.vec_i = true;
        }
        return ship;
    }
    if !held[i].vecs {
        held[i].vecs = true;
        ship.vec_i = true;
    }
    if has_cache && !held[i].tree {
        held[i].tree = true;
        ship.tree_i = true;
    }
    if !held[j].vecs {
        held[j].vecs = true;
        ship.vec_j = true;
    }
    if has_cache && !held[j].tree {
        held[j].tree = true;
        ship.tree_j = true;
    }
    ship
}

/// Wire bytes of one pair-job scatter under `ship`: exactly the length of
/// the `PairAssign` frame the remote link encodes for it (header + the
/// shipped sections) — the arithmetic delegates to [`crate::net::wire`], so
/// the modeled charge and the measured frame cannot drift.
fn shipment_bytes(
    plan: &ExecPlan,
    job: &PairJob,
    d: usize,
    cache: Option<&LocalMstCache>,
    ship: &Shipment,
) -> u64 {
    let tree_bytes = |k: usize| {
        cache.map_or(0, |c| c.trees[k].len() as u64 * Edge::WIRE_BYTES as u64)
    };
    let mut bytes = HEADER_BYTES;
    if ship.vec_i {
        bytes += subset_payload_bytes(plan, job.i as usize, d);
    }
    if ship.tree_i {
        bytes += tree_bytes(job.i as usize);
    }
    if ship.vec_j {
        bytes += subset_payload_bytes(plan, job.j as usize, d);
    }
    if ship.tree_j {
        bytes += tree_bytes(job.j as usize);
    }
    bytes
}

/// Vector-section bytes of one shipment — the part of the scatter that is
/// leader-held vector payload (zero whenever only cached trees travel).
fn ship_vector_bytes(plan: &ExecPlan, job: &PairJob, d: usize, ship: &Shipment) -> u64 {
    let mut bytes = 0;
    if ship.vec_i {
        bytes += subset_payload_bytes(plan, job.i as usize, d);
    }
    if ship.vec_j {
        bytes += subset_payload_bytes(plan, job.j as usize, d);
    }
    bytes
}

/// One subset's share of a pair-job scatter: global-id map + vectors.
/// `job_wire_bytes(|S_i| + |S_j|, d) = HEADER_BYTES + Σ` of these, which is
/// what keeps the dense and resident-set models consistent per subset.
fn subset_payload_bytes(plan: &ExecPlan, k: usize, d: usize) -> u64 {
    crate::net::wire::vectors_payload_bytes(plan.parts[k].len(), d)
}

/// Build the local-MST cache through the worker pool: one job per
/// partition, heaviest first — at its anchor worker when affinity is on
/// (idle stealing as fallback), in which case the builder marks the subset
/// resident so the pair phase's byte model does not re-ship it. Scatter
/// charges each subset's vectors exactly once either way; gather charges
/// each returned local tree once. Under a remote transport, pool thread `w`
/// ships the subset as a `LocalJob` frame to remote worker `w` — which
/// keeps it resident and computes the tree over the gathered rows
/// (bit-identical, see [`crate::exec::pair_kernel::subset_mst_gathered`]).
/// On *sharded* runs the vectors are already worker-resident, so the frame
/// degenerates to a header-only `LocalAssign` and the capability mask
/// confines each subset to its holders. A worker whose link dies here is
/// marked dead, its subsets return to the lane, and the surviving holders
/// rebuild them. Also returns each pool worker's busy time so the engine
/// can attribute this phase's compute to `RunMetrics::worker_busy` (remote
/// compute is the worker-measured time from the `LocalDone` frame, not the
/// round-trip), plus each subset's **building anchor** — the worker whose
/// tree cache holds it, which the peer data plane routes tree fetches to
/// (`FOLD_KEEP` marks a subset built in-process, never routable).
fn build_cache_pooled(
    ds: Option<&Dataset>,
    d: usize,
    ctx: Option<&BipartiteCtx>,
    plan: &ExecPlan,
    n_workers: usize,
    cfg: &RunConfig,
    net: &dyn Transport,
    affinity: Option<&AffinityPlan>,
    holders: Option<&[Vec<bool>]>,
    residents: &[Mutex<Vec<Held>>],
    remote: Option<&TcpTransport>,
    fleet: &Fleet,
    witness: &ByteWitness,
    obs_run: Option<crate::obs::RunToken>,
    reg: &crate::obs::metrics::Registry,
) -> anyhow::Result<(LocalMstCache, Vec<Duration>, Vec<u16>)> {
    let t = Instant::now();
    let p = plan.parts.len();
    let queue = match (affinity, holders) {
        (Some(aff), Some(h)) => {
            JobQueue::with_decks_capped(aff.local_decks.clone(), h.to_vec())
        }
        (Some(aff), None) => JobQueue::with_decks(aff.local_decks.clone()),
        (None, _) => {
            let mut order: Vec<usize> = (0..p).collect();
            order.sort_by(|&a, &b| plan.parts[b].len().cmp(&plan.parts[a].len()).then(a.cmp(&b)));
            JobQueue::new(order)
        }
    };
    let counter = CountingMetric::new(cfg.metric);
    let slots: Vec<Mutex<Option<Vec<Edge>>>> = (0..p).map(|_| Mutex::new(None)).collect();
    // Which worker's cache holds each subset's tree (last builder wins on
    // elastic rebuilds — exactly the copy that is still alive).
    let anchors: Vec<AtomicU32> = (0..p).map(|_| AtomicU32::new(u32::MAX)).collect();
    let built = AtomicUsize::new(0);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let n_spawn = if remote.is_some() { n_workers } else { n_workers.min(p) };
    let busy: Vec<Mutex<Duration>> = (0..n_spawn).map(|_| Mutex::new(Duration::ZERO)).collect();
    std::thread::scope(|scope| {
        let queue_ref = &queue;
        let counter_ref = &counter;
        let slots_ref = &slots;
        let anchors_ref = &anchors;
        let built_ref = &built;
        let errors_ref = &errors;
        for (w, busy_slot) in busy.iter().enumerate() {
            let resident = &residents[w];
            scope.spawn(move || {
                if let Some(t) = obs_run {
                    crate::obs::adopt(t);
                }
                loop {
                    let claimed = queue_ref.pop_for(w);
                    let Some((k, _stolen)) = claimed else {
                        match remote {
                            None => return, // in-process: a drained queue is final
                            Some(_) => {
                                if built_ref.load(Ordering::SeqCst) >= p || fleet.aborted() {
                                    return;
                                }
                                if let Some(k) = queue_ref.stranded_job(&fleet.alive()) {
                                    errors_ref.lock().unwrap().push(format!(
                                        "subset {k}: every worker holding it has failed"
                                    ));
                                    fleet.abort.store(true, Ordering::SeqCst);
                                    return;
                                }
                                std::thread::sleep(Duration::from_millis(1));
                                continue;
                            }
                        }
                    };
                    let ids = &plan.parts[k];
                    let sharded = ds.is_none();
                    let tree = if let Some(tcp) = remote {
                        let msg = if sharded {
                            Message::LocalAssign { part: k as u32 }
                        } else {
                            Message::LocalJob {
                                part: k as u32,
                                global_ids: ids.clone(),
                                points: ds.expect("unsharded remote holds the dataset").gather(ids),
                            }
                        };
                        // Ingest accounted only after the frame actually left:
                        // a failed send returns the subset to the lane and the
                        // survivor's re-send is the transfer that counts.
                        let reply = tcp.send_to(w, &msg, Direction::Scatter).and_then(|_| {
                            if let Some(ds) = ds {
                                let payload =
                                    crate::net::wire::vectors_payload_bytes(ids.len(), ds.d);
                                witness.ingest.fetch_add(payload, Ordering::Relaxed);
                                witness.data.fetch_add(payload, Ordering::Relaxed);
                            }
                            tcp.recv_from(w)
                        });
                        match reply {
                            Ok(Message::LocalDone { part, edges, compute })
                                if part as usize == k =>
                            {
                                *busy_slot.lock().unwrap() += compute;
                                edges
                            }
                            Ok(other) => {
                                // recovery state first, dead flag last (see
                                // Fleet::fail_worker)
                                queue_ref.push_returned(&[k]);
                                queue_ref.abandon_deck(w);
                                fleet.reassigned.fetch_add(1, Ordering::Relaxed);
                                fleet.fail_worker(w);
                                crate::obs::log!(
                                    error,
                                    "leader: worker {w} answered subset {k} with {other:?}; treating the link as failed"
                                );
                                return;
                            }
                            Err(e) => {
                                queue_ref.push_returned(&[k]);
                                queue_ref.abandon_deck(w);
                                fleet.reassigned.fetch_add(1, Ordering::Relaxed);
                                let stalled = crate::net::is_stall(&e);
                                if stalled {
                                    fleet.stalls.fetch_add(1, Ordering::Relaxed);
                                }
                                fleet.fail_worker(w);
                                if stalled {
                                    crate::obs::instant(
                                        crate::obs::SpanKind::Stall,
                                        crate::obs::trace::LEADER_TRACK,
                                        w as u32,
                                        0,
                                    );
                                }
                                crate::obs::log!(
                                    warn,
                                    "leader: worker {w} link {} on subset {k} ({e:#}); returned it to the deck",
                                    if stalled { "stalled" } else { "failed" }
                                );
                                return;
                            }
                        }
                    } else {
                        let ds = ds.expect("in-process phase 1 holds the dataset");
                        let ctx = ctx.expect("in-process phase 1 carries the bipartite context");
                        // the modeled scatter of this subset's vectors (the
                        // in-process "transfer" is the model and cannot fail)
                        net.charge(job_wire_bytes(ids.len(), ds.d), Direction::Scatter);
                        let payload = crate::net::wire::vectors_payload_bytes(ids.len(), ds.d);
                        witness.ingest.fetch_add(payload, Ordering::Relaxed);
                        witness.data.fetch_add(payload, Ordering::Relaxed);
                        let mut span =
                            crate::obs::span(crate::obs::SpanKind::LocalMst, w as u16, k as u32);
                        let t_job = Instant::now();
                        let tree = subset_mst(
                            ds.as_slice(),
                            ds.d,
                            ctx.block.as_ref(),
                            &ctx.aux,
                            counter_ref,
                            ids,
                        );
                        let dt = t_job.elapsed();
                        *busy_slot.lock().unwrap() += dt;
                        // Exact by partition shape (the shared counter can't
                        // give a clean per-thread delta): Prim over m points
                        // always evaluates C(m, 2) pairs.
                        let m = ids.len() as u64;
                        let evals = m * m.saturating_sub(1) / 2;
                        span.set_arg(evals);
                        drop(span);
                        // In-process only: on remote runs the worker's own
                        // registry recorded this build, and its snapshot
                        // reaches the hub over the wire.
                        reg.observe(
                            crate::obs::metrics::Hist::LocalMst,
                            u64::try_from(dt.as_nanos()).unwrap_or(u64::MAX),
                        );
                        reg.add(crate::obs::metrics::Ctr::DistEvals, evals);
                        tree
                    };
                    net.charge(
                        HEADER_BYTES + tree.len() as u64 * Edge::WIRE_BYTES as u64,
                        Direction::Gather,
                    );
                    {
                        // the claiming worker now holds the subset's vectors
                        // (already true on sharded runs) and its cached tree
                        let mut res = resident.lock().unwrap();
                        res[k].vecs = true;
                        res[k].tree = true;
                    }
                    *slots_ref[k].lock().unwrap() = Some(tree);
                    anchors_ref[k].store(w as u32, Ordering::Relaxed);
                    built_ref.fetch_add(1, Ordering::SeqCst);
                }
            });
        }
    });
    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        anyhow::bail!("local-MST phase failed: {}", errors.join("; "));
    }
    let trees: Vec<Vec<Edge>> = slots
        .into_iter()
        .enumerate()
        .map(|(k, s)| {
            s.into_inner()
                .unwrap()
                .ok_or_else(|| anyhow::anyhow!("subset {k} local MST missing (worker failure?)"))
        })
        .collect::<anyhow::Result<_>>()?;
    // The remote workers did the cache evaluations; the count is exact from
    // the partition shape (Prim over m points is always C(m, 2) pairs) —
    // identical to what the in-process CountingMetric records.
    let evals = if remote.is_some() {
        plan.parts
            .iter()
            .map(|p| {
                let m = p.len() as u64;
                m * m.saturating_sub(1) / 2
            })
            .sum()
    } else {
        counter.evals()
    };
    let busy: Vec<Duration> = busy.into_iter().map(|b| b.into_inner().unwrap()).collect();
    let anchors: Vec<u16> = anchors
        .into_iter()
        .map(|a| {
            let a = a.into_inner();
            if a == u32::MAX { FOLD_KEEP } else { a as u16 }
        })
        .collect();
    Ok((LocalMstCache { trees, evals, build_time: t.elapsed() }, busy, anchors))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::KernelChoice;
    use crate::data::generators::uniform;
    use crate::decomp::decomposed_mst;
    use crate::dense::PrimDense;
    use crate::geometry::MetricKind;
    use crate::mst::normalize_tree;
    use crate::net::NetSim;
    use crate::util::prng::Pcg64;

    fn int_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(25) as f32 - 12.0).collect();
        Dataset::new(n, d, data)
    }

    #[test]
    fn bipartite_serial_matches_dense_serial() {
        let ds = int_dataset(500, 70, 6);
        for parts in [1usize, 2, 3, 5, 7] {
            let cfg = DecompConfig { parts, ..Default::default() };
            let dense = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
            let bip = decomposed_mst_bipartite(&ds, &cfg, MetricKind::SqEuclid);
            assert_eq!(
                normalize_tree(&dense.mst),
                normalize_tree(&bip.mst),
                "parts={parts}"
            );
            let n = ds.n as u64;
            assert_eq!(bip.dist_evals, n * (n - 1) / 2, "parts={parts}: exactly C(n,2)");
            if parts >= 3 {
                assert!(
                    bip.dist_evals < dense.dist_evals,
                    "parts={parts}: bipartite {} !< dense {}",
                    bip.dist_evals,
                    dense.dist_evals
                );
            }
        }
    }

    #[test]
    fn pooled_bipartite_matches_pooled_dense_all_worker_counts() {
        let ds = int_dataset(501, 80, 5);
        let mut cfg = RunConfig {
            parts: 4,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let dense = execute_pooled(&ds, &cfg, &net).unwrap();
        cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
        for workers in [1usize, 3, 6] {
            cfg.workers = workers;
            let net = NetSim::new(cfg.net.clone());
            let bip = execute_pooled(&ds, &cfg, &net).unwrap();
            assert_eq!(
                normalize_tree(&dense.mst),
                normalize_tree(&bip.mst),
                "workers={workers}"
            );
            let n = ds.n as u64;
            assert_eq!(bip.metrics.dist_evals, n * (n - 1) / 2, "workers={workers}");
            assert_eq!(
                bip.metrics.local_mst_evals + bip.metrics.pair_evals,
                bip.metrics.dist_evals
            );
            assert!(bip.metrics.local_mst_evals > 0);
        }
    }

    #[test]
    fn stream_reduce_matches_batch() {
        let ds = int_dataset(502, 60, 4);
        let mut cfg = RunConfig {
            parts: 5,
            workers: 3,
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let batch = execute_pooled(&ds, &cfg, &net).unwrap();
        cfg.stream_reduce = true;
        let net = NetSim::new(cfg.net.clone());
        let streamed = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(normalize_tree(&batch.mst), normalize_tree(&streamed.mst));
        assert_eq!(batch.metrics.union_edges, streamed.metrics.union_edges);
        assert!(streamed.metrics.stream_reduce);
        // streaming also composes with worker-local ⊕-reduction
        cfg.reduce_tree = true;
        let net = NetSim::new(cfg.net.clone());
        let both = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(normalize_tree(&batch.mst), normalize_tree(&both.mst));
    }

    #[test]
    fn lpt_scatter_bytes_match_dense_model() {
        // With affinity routing off, the pull-based scheduler must charge
        // the identical per-job scatter the eager round-robin leader
        // charged — the dense model stays available byte-for-byte.
        let ds = uniform(96, 7, 1.0, Pcg64::seeded(503));
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            strategy: crate::decomp::PartitionStrategy::Block,
            affinity: false,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        let m = 2 * 96 / 4;
        let per_job = 16 + m as u64 * 4 + (m * 7) as u64 * 4;
        assert_eq!(out.metrics.scatter_bytes, 6 * per_job);
        assert_eq!(out.metrics.scatter_saved_bytes, 0, "dense model saves nothing");
        assert_eq!(out.metrics.jobs_stolen, 0, "single shared deck: nothing counts as stolen");
        // every scattered byte above is leader-held vector payload + header
        assert_eq!(out.metrics.leader_ingest_bytes, 6 * (per_job - 16));
        assert_eq!(out.metrics.worker_failures, 0);
        assert_eq!(out.metrics.jobs_reassigned, 0);
        assert!(!out.metrics.sharded);
    }

    /// The resident-set invariant that makes the affinity model auditable:
    /// per job, charged + saved equals the dense model exactly, so for any
    /// seed/strategy/worker count `affinity.scatter + affinity.saved ==
    /// dense.scatter` — and the tree is unchanged.
    #[test]
    fn affinity_scatter_plus_saved_equals_dense_model() {
        let ds = int_dataset(505, 90, 6);
        for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            for workers in [1usize, 2, 4] {
                let mut cfg = RunConfig {
                    parts: 5,
                    workers,
                    kernel: KernelChoice::PrimDense,
                    pair_kernel,
                    ..Default::default()
                };
                cfg.affinity = false;
                let net = NetSim::new(cfg.net.clone());
                let dense = execute_pooled(&ds, &cfg, &net).unwrap();
                cfg.affinity = true;
                let net = NetSim::new(cfg.net.clone());
                let aff = execute_pooled(&ds, &cfg, &net).unwrap();
                assert_eq!(
                    normalize_tree(&dense.mst),
                    normalize_tree(&aff.mst),
                    "{pair_kernel:?} workers={workers}: affinity must not change the tree"
                );
                assert_eq!(
                    aff.metrics.scatter_bytes + aff.metrics.scatter_saved_bytes,
                    dense.metrics.scatter_bytes,
                    "{pair_kernel:?} workers={workers}: charged + saved == dense model"
                );
                assert!(
                    aff.metrics.scatter_bytes <= dense.metrics.scatter_bytes,
                    "{pair_kernel:?} workers={workers}: affinity can never charge more"
                );
                assert_eq!(aff.metrics.dist_evals, dense.metrics.dist_evals);
            }
        }
    }

    /// parts ≥ 4 with few workers: by pigeonhole some worker runs more pair
    /// jobs than a maximum matching over the subsets, so at least one job
    /// must share a subset with an earlier job on that worker — the
    /// resident-set model saves strictly positive bytes, for both kernels.
    #[test]
    fn affinity_saves_strictly_for_parts_ge_4() {
        let ds = int_dataset(506, 80, 5);
        for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            for workers in [1usize, 2] {
                let cfg = RunConfig {
                    parts: 4,
                    workers,
                    kernel: KernelChoice::PrimDense,
                    pair_kernel,
                    ..Default::default()
                };
                let net = NetSim::new(cfg.net.clone());
                let out = execute_pooled(&ds, &cfg, &net).unwrap();
                assert!(
                    out.metrics.scatter_saved_bytes > 0,
                    "{pair_kernel:?} workers={workers}: expected strict scatter savings"
                );
            }
        }
    }

    #[test]
    fn bipartite_panel_cache_metrics_populated() {
        let ds = int_dataset(507, 64, 4);
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            strategy: crate::decomp::PartitionStrategy::Block,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        // 6 cross jobs × 2 panel probes, however they land on the workers
        assert_eq!(out.metrics.panel_hits + out.metrics.panel_misses, 12);
        // 2 workers, 6 jobs: some worker ran ≥ 3 jobs over 4 subsets, which
        // cannot be pairwise disjoint — at least one probe hit
        assert!(out.metrics.panel_hits > 0, "panel cache never hit");
        assert!(out.metrics.panel_hit_rate() > 0.0);
    }

    #[test]
    fn stream_reduce_fold_metrics_populated() {
        let ds = int_dataset(508, 60, 4);
        let cfg = RunConfig {
            parts: 5,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            stream_reduce: true,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(out.metrics.reduce_folds, 10, "one fold per pair tree");
        assert!(out.metrics.reduce_fold_edges > 0);
        assert!(
            out.metrics.reduce_fold_edges <= out.metrics.reduce_folds as u64 * 2 * (ds.n as u64 - 1),
            "streaming folds stay O(|V|) each: {} edges over {} folds",
            out.metrics.reduce_fold_edges,
            out.metrics.reduce_folds
        );
    }

    #[test]
    fn bipartite_phase_metrics_populated() {
        let ds = int_dataset(504, 64, 4);
        let cfg = RunConfig {
            parts: 4,
            workers: 2,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            strategy: crate::decomp::PartitionStrategy::Block,
            ..Default::default()
        };
        let net = NetSim::new(cfg.net.clone());
        let out = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(out.metrics.pair_kernel, "bipartite-merge");
        assert!(out.metrics.kernel.contains("bipartite-merge"), "{}", out.metrics.kernel);
        assert!(out.metrics.phase_local_mst > Duration::ZERO);
        assert!(out.metrics.phase_pair > Duration::ZERO);
        // 4 partitions of 16: cache = 4 * C(16,2), pairs = 6 * 16 * 16
        assert_eq!(out.metrics.local_mst_evals, 4 * (16 * 15 / 2));
        assert_eq!(out.metrics.pair_evals, 6 * 16 * 16);
    }

    /// The split-flag resident model must reproduce the historical
    /// one-flag shipments on every leader-resident sequence: vectors and
    /// trees always travel (and are marked) together.
    #[test]
    fn residual_shipment_marks_vectors_and_trees_together() {
        let mut held = vec![Held::default(); 3];
        let job01 = PairJob { id: 0, i: 0, j: 1 };
        let job12 = PairJob { id: 1, i: 1, j: 2 };
        let s = residual_shipment(&job01, true, &mut held);
        assert_eq!(
            s,
            Shipment {
                vec_i: true,
                vec_j: true,
                tree_i: true,
                tree_j: true,
                ..Default::default()
            }
        );
        let s = residual_shipment(&job12, true, &mut held);
        assert_eq!(
            s,
            Shipment { vec_j: true, tree_j: true, ..Default::default() },
            "subset 1 already fully held"
        );
        // sharded seeding: vectors pre-held, only trees ship
        let mut held = vec![Held { vecs: true, tree: false }; 3];
        let s = residual_shipment(&job01, true, &mut held);
        assert_eq!(s, Shipment { tree_i: true, tree_j: true, ..Default::default() });
        let s = residual_shipment(&job01, true, &mut held);
        assert_eq!(s, Shipment::default(), "everything held on repeat");
        // dense kernel on a sharded run ships nothing at all
        let mut held = vec![Held { vecs: true, tree: false }; 3];
        assert_eq!(residual_shipment(&job01, false, &mut held), Shipment::default());
    }

    /// Every ⊕-fold ship must ascend worker ids (drivers settle in id
    /// order) and root at the highest alive id, for both schedules and
    /// with dead workers dropped out.
    #[test]
    fn fold_target_schedules_ascend_and_root_at_highest() {
        let alive = vec![true; 4];
        for w in 0..4 {
            assert_eq!(fold_target(ReduceTopology::Leader, &alive, w), FOLD_KEEP);
        }
        assert_eq!(fold_target(ReduceTopology::Ring, &alive, 0), 1);
        assert_eq!(fold_target(ReduceTopology::Ring, &alive, 1), 2);
        assert_eq!(fold_target(ReduceTopology::Ring, &alive, 2), 3);
        assert_eq!(fold_target(ReduceTopology::Ring, &alive, 3), FOLD_KEEP);
        // mirrored binomial over 4: 0→1, 1→3, 2→3, root 3
        assert_eq!(fold_target(ReduceTopology::Tree, &alive, 0), 1);
        assert_eq!(fold_target(ReduceTopology::Tree, &alive, 1), 3);
        assert_eq!(fold_target(ReduceTopology::Tree, &alive, 2), 3);
        assert_eq!(fold_target(ReduceTopology::Tree, &alive, 3), FOLD_KEEP);
        // exhaustive invariants over fleet sizes and death masks
        for m in 1usize..6 {
            for mask in 0..(1u32 << m) {
                let alive: Vec<bool> = (0..m).map(|w| mask & (1 << w) != 0).collect();
                let ids: Vec<usize> = (0..m).filter(|&w| alive[w]).collect();
                if ids.is_empty() {
                    continue;
                }
                let root = *ids.last().unwrap();
                let mut kept = 0;
                for &w in &ids {
                    for topo in [ReduceTopology::Tree, ReduceTopology::Ring] {
                        let t = fold_target(topo, &alive, w);
                        if w == root {
                            assert_eq!(t, FOLD_KEEP, "{topo:?}: root must keep");
                        }
                        if t != FOLD_KEEP {
                            assert!(
                                (t as usize) > w && alive[t as usize],
                                "{topo:?}: ships ascend to alive ids"
                            );
                        }
                    }
                    if fold_target(ReduceTopology::Tree, &alive, w) == FOLD_KEEP {
                        kept += 1;
                    }
                }
                assert_eq!(kept, 1, "tree schedule has exactly one root");
            }
        }
    }

    /// Tree and ring reductions must produce the leader topology's exact
    /// tree while moving the per-worker partials onto the peer plane —
    /// the leader's gather shrinks to bare stats frames plus one root MSF.
    #[test]
    fn sim_reduce_topologies_bit_identical_and_offload_gather() {
        let ds = int_dataset(509, 80, 5);
        for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
            let base = RunConfig {
                parts: 5,
                workers: 3,
                kernel: KernelChoice::PrimDense,
                pair_kernel,
                reduce_tree: true,
                ..Default::default()
            };
            let net = NetSim::new(base.net.clone());
            let leader = execute_pooled(&ds, &base, &net).unwrap();
            assert_eq!(leader.metrics.reduce_topology, "leader");
            assert_eq!(leader.metrics.peer_bytes, 0);
            for topology in [ReduceTopology::Tree, ReduceTopology::Ring] {
                let cfg = RunConfig { reduce_topology: topology, ..base.clone() };
                let net = NetSim::new(cfg.net.clone());
                let out = execute_pooled(&ds, &cfg, &net).unwrap();
                assert_eq!(
                    normalize_tree(&leader.mst),
                    normalize_tree(&out.mst),
                    "{pair_kernel:?} {topology:?}: reduction topology changed the tree"
                );
                assert_eq!(out.metrics.jobs, leader.metrics.jobs);
                assert_eq!(out.metrics.reduce_topology, topology.name());
                assert!(
                    out.metrics.peer_bytes > 0,
                    "{pair_kernel:?} {topology:?}: fold ships must ride the peer plane"
                );
                assert!(
                    out.metrics.gather_bytes < leader.metrics.gather_bytes,
                    "{pair_kernel:?} {topology:?}: gather {} must shrink below leader {}",
                    out.metrics.gather_bytes,
                    leader.metrics.gather_bytes
                );
            }
        }
    }

    /// Peer-routed tree scatter extends the resident-set reconciliation:
    /// the leader's charges plus the model's recorded savings still equal
    /// the dense ship-everything model, with the routed payload carried by
    /// the peer counter instead.
    #[test]
    fn peer_routed_scatter_reconciles_with_dense_model() {
        let ds = int_dataset(510, 80, 5);
        let mut cfg = RunConfig {
            parts: 5,
            workers: 3,
            kernel: KernelChoice::PrimDense,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            ..Default::default()
        };
        cfg.affinity = false;
        let net = NetSim::new(cfg.net.clone());
        let dense = execute_pooled(&ds, &cfg, &net).unwrap();
        assert!(!dense.metrics.peer_route);
        cfg.affinity = true;
        cfg.peer_route = Some(true);
        let net = NetSim::new(cfg.net.clone());
        let routed = execute_pooled(&ds, &cfg, &net).unwrap();
        assert_eq!(
            normalize_tree(&dense.mst),
            normalize_tree(&routed.mst),
            "peer routing must not change the tree"
        );
        assert!(routed.metrics.peer_route);
        assert_eq!(
            routed.metrics.scatter_bytes + routed.metrics.scatter_saved_bytes,
            dense.metrics.scatter_bytes,
            "charged + saved == dense model must survive routing"
        );
        assert!(
            routed.metrics.peer_bytes > 0,
            "some cross-anchor tree must have traveled worker↔worker"
        );
        assert!(
            routed.metrics.scatter_bytes < dense.metrics.scatter_bytes,
            "routing must strictly shrink the leader's scatter"
        );
    }
}
