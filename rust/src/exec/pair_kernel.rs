//! Pair-job solvers: how one `d-MST(S_i ∪ S_j)` job gets computed.
//!
//! Two interchangeable kernels sit behind [`PairSolver`]:
//!
//! - [`DensePairSolver`] — the paper-literal path: gather the union, run a
//!   full dense d-MST kernel ([`DenseMst`]) over it. Every pair re-solves
//!   both subsets' internal distance structure, so each subset's internal
//!   work is repeated `|P| - 1` times. Kept as the selectable oracle.
//! - [`BipartitePairSolver`] — the cycle-property kernel: each subset's
//!   local MST is computed **exactly once** (the [`LocalMstCache`]), and a
//!   pair job runs a *filtered Prim* over the sparse graph
//!   `MST(S_i) ∪ MST(S_j) ∪ bipartite(S_i × S_j)`. Only the bipartite block
//!   is evaluated fresh — computed as **one `S_i × S_j` panel product**
//!   ([`DistanceBlock::panel_block`] over packed [`SubsetPanel`]s served
//!   from a per-worker [`PanelCache`], so jobs sharing a subset reuse its
//!   packed rows and norms) — and a full run performs exactly `n(n-1)/2`
//!   distance evaluations *total* — the same as a monolithic dense MST —
//!   versus the dense pair path's `≈ 2(|P|-1)/|P| · n(n-1)/2`.
//!
//! Exactness of the filter (cycle property under the strict `(w, u, v)`
//! order): an edge internal to `S_i` that is not in `MST(S_i)` closes a
//! cycle inside `S_i` on which it is the strict maximum; that cycle also
//! exists in `S_i ∪ S_j`, so the edge cannot be in `MST(S_i ∪ S_j)`. Hence
//! `MST(S_i ∪ S_j) ⊆ MST(S_i) ∪ MST(S_j) ∪ bipartite(S_i, S_j)` and the
//! filtered Prim returns the identical canonical tree as the dense kernel
//! (both consume bit-identical [`DistanceBlock`] arithmetic).
//!
//! All merge-path comparisons happen in the metric's *compare form*
//! (squared for Euclid); weights are mapped to emission form only on the
//! returned pair tree, exactly where the dense kernels do it.

use super::plan::ExecPlan;
use crate::data::Dataset;
use crate::decomp::algorithm::{merge_sorted_ids, run_pair};
use crate::decomp::PairJob;
use crate::dense::DenseMst;
use crate::geometry::blocked::{distance_block_with, DistanceBlock};
use crate::geometry::simd::{self, PanelSettings};
use crate::geometry::{CountingMetric, MetricKind};
use crate::graph::Edge;
use crate::util::fkey::edge_cmp;
use std::cmp::Ordering;
use std::time::{Duration, Instant};

/// Which parts of a pair job's payload travel to the executing worker: the
/// two subsets' vectors and (bipartite-merge kernel) their cached local
/// MSTs. The engine computes this once per job from the resident-set model,
/// charges exactly its byte size, and hands it to the solver — in-process
/// solvers ignore it (they share memory), the remote proxy ships precisely
/// these sections, which is what keeps the modeled and measured scatter
/// byte-for-byte equal.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Shipment {
    pub vec_i: bool,
    pub vec_j: bool,
    pub tree_i: bool,
    pub tree_j: bool,
    /// peer-route subset `i`'s tree: ship a zero-payload routed section and
    /// let the worker pull the tree from its building anchor over a peer
    /// link (mutually exclusive with `tree_i`)
    pub route_i: bool,
    /// peer-route subset `j`'s tree (mutually exclusive with `tree_j`)
    pub route_j: bool,
}

/// One solved pair job. `compute` is the remotely measured kernel time when
/// the solve happened in another process (the engine's own stopwatch would
/// otherwise fold wire time into the compute metrics); `None` means "use
/// the caller's local measurement".
#[derive(Clone, Debug)]
pub struct Solved {
    pub edges: Vec<Edge>,
    pub compute: Option<Duration>,
}

/// End-of-run stats a solver reports when its queue drains. For in-process
/// solvers this is just the counters; the remote proxy fills it from the
/// worker process's final `WorkerDone` frame (including the remotely
/// ⊕-folded tree in reduce mode and the remotely measured busy time).
#[derive(Clone, Debug, Default)]
pub struct SolverFinal {
    pub dist_evals: u64,
    pub panel_hits: u64,
    pub panel_misses: u64,
    /// measured panel-kernel work (FLOPs / wall time / threads / ISA) —
    /// zeros for solvers that never run a panel (the dense kernel)
    pub panel_perf: PanelPerf,
    /// remote-measured kernel busy time, when the compute happened in
    /// another process (overrides the proxy's round-trip measurement)
    pub busy: Option<Duration>,
    /// remotely ⊕-folded worker tree (reduce mode on a remote solver)
    pub local_tree: Option<Vec<Edge>>,
    /// bytes the remote worker sent over **peer** links (routed tree ships
    /// + ⊕-fold hops) — zero for in-process solvers
    pub peer_tx_bytes: u64,
    /// peer-plane frames the remote worker sent
    pub peer_ships: u32,
    /// telemetry spans the remote worker shipped back (tracing runs only;
    /// in-process solvers record straight into the leader's buffers)
    pub spans: Vec<crate::obs::Span>,
    /// the remote worker's [`crate::obs::now_ns`] at `WorkerDone` send
    /// time, for re-basing its span timestamps onto the leader's clock
    pub now_ns: u64,
    /// chaos-transport faults the remote worker's link injected
    pub chaos_faults: u32,
    /// the remote worker's final cumulative metrics snapshot (metrics-armed
    /// runs only; in-process solvers record straight into the run's hub)
    pub metrics: Option<crate::obs::metrics::Snapshot>,
}

/// Measured `panel_block` work, the witnesses behind the `kernel:` line and
/// the `panel_*` run metrics: total FLOPs (2·m·n·d dot-form, 3·m·n·d
/// Manhattan), wall time inside the panel kernel, the largest per-panel
/// thread plan, and the dispatched ISA as its wire code (0 = no panel ran).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PanelPerf {
    pub flops: u64,
    pub time: Duration,
    pub threads: u32,
    pub isa: u8,
}

/// A solver for one pair job. `job.i == job.j` is the degenerate
/// single-subset job (`|P| = 1`). Returned edges carry global vertex ids and
/// emission-form weights.
pub trait PairSolver {
    fn solve(&mut self, plan: &ExecPlan, job: &PairJob) -> Vec<Edge>;

    /// Solve with an explicit payload shipment (the pooled engine's entry
    /// point). In-process solvers share the leader's memory and ignore the
    /// shipment; the remote proxy overrides this to put it on the wire.
    fn solve_shipped(
        &mut self,
        plan: &ExecPlan,
        job: &PairJob,
        _ship: &Shipment,
    ) -> anyhow::Result<Solved> {
        Ok(Solved { edges: self.solve(plan, job), compute: None })
    }

    /// Distance evaluations performed by *this solver* so far (for the
    /// bipartite kernel this excludes the shared local-MST cache build,
    /// which is accounted separately by the engine).
    fn dist_evals(&self) -> u64;

    /// `(hits, misses)` of this solver's subset-panel cache; `(0, 0)` for
    /// solvers without one (the dense kernel).
    fn panel_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Measured panel-kernel work; zeros for solvers without a panel path.
    fn panel_perf(&self) -> PanelPerf {
        PanelPerf::default()
    }

    /// Drain-time stats. The remote proxy's override performs the shutdown
    /// rendezvous with its worker process.
    fn finish(&mut self) -> anyhow::Result<SolverFinal> {
        let (panel_hits, panel_misses) = self.panel_stats();
        Ok(SolverFinal {
            dist_evals: self.dist_evals(),
            panel_hits,
            panel_misses,
            panel_perf: self.panel_perf(),
            ..SolverFinal::default()
        })
    }
}

/// The dense pair kernel: `d-MST(S_i ∪ S_j)` via a full [`DenseMst`] run
/// over the gathered union (the paper's literal Algorithm 1 inner loop).
pub struct DensePairSolver<'a> {
    ds: &'a Dataset,
    kernel: KernelRef<'a>,
}

enum KernelRef<'a> {
    Owned(Box<dyn DenseMst>),
    Borrowed(&'a dyn DenseMst),
}

impl<'a> DensePairSolver<'a> {
    /// Solver owning its kernel (pooled execution: one kernel per worker).
    pub fn owned(ds: &'a Dataset, kernel: Box<dyn DenseMst>) -> Self {
        Self { ds, kernel: KernelRef::Owned(kernel) }
    }

    /// Solver borrowing the caller's kernel (serial execution keeps the
    /// caller's eval counters observable).
    pub fn borrowed(ds: &'a Dataset, kernel: &'a dyn DenseMst) -> Self {
        Self { ds, kernel: KernelRef::Borrowed(kernel) }
    }

    fn kernel(&self) -> &dyn DenseMst {
        match &self.kernel {
            KernelRef::Owned(k) => k.as_ref(),
            KernelRef::Borrowed(k) => *k,
        }
    }
}

impl PairSolver for DensePairSolver<'_> {
    fn solve(&mut self, plan: &ExecPlan, job: &PairJob) -> Vec<Edge> {
        let si = &plan.parts[job.i as usize];
        let sj: &[u32] = if job.i == job.j { &[] } else { &plan.parts[job.j as usize] };
        run_pair(self.ds, si, sj, self.kernel())
    }

    fn dist_evals(&self) -> u64 {
        self.kernel().dist_evals()
    }
}

/// Shared per-run context for the bipartite-merge kernel: the blocked
/// distance implementation plus its per-row auxiliary values (norms)
/// prepared **once** over the full matrix and reused by every local-MST and
/// bipartite-row computation.
pub struct BipartiteCtx {
    pub kind: MetricKind,
    pub block: Box<dyn DistanceBlock>,
    pub aux: Vec<f32>,
    /// weights compare in squared form and need a `sqrt` at emission
    pub sqrt_at_emit: bool,
    /// panel dispatch settings the block was built with (for thread-plan
    /// and ISA witnesses; pure speed knobs — bit-identical by contract)
    pub panel: PanelSettings,
    /// when set, solvers try to route sqeuclid/euclid panels through the
    /// AOT XLA pairwise artifact in this directory, falling back to the
    /// SIMD path per call (PJRT handles are thread-local, so each solver
    /// loads its own engine). Only honored in `backend-xla` builds.
    pub xla_panels: Option<std::path::PathBuf>,
}

impl BipartiteCtx {
    /// Environment-driven defaults ([`PanelSettings::detect`]), no XLA
    /// panel routing — what tests and the serial reference path use.
    pub fn new(ds: &Dataset, kind: MetricKind) -> Self {
        Self::with_settings(ds, kind, PanelSettings::detect(), None)
    }

    /// Explicit panel settings (the engine resolves them from `RunConfig`)
    /// and optional XLA panel routing.
    pub fn with_settings(
        ds: &Dataset,
        kind: MetricKind,
        panel: PanelSettings,
        xla_panels: Option<std::path::PathBuf>,
    ) -> Self {
        let block = distance_block_with(kind, panel);
        let aux = block.prepare(ds.as_slice(), ds.n, ds.d);
        let sqrt_at_emit = block.compare_form_is_squared();
        Self { kind, block, aux, sqrt_at_emit, panel, xla_panels }
    }
}

/// Each partition's local MST, computed exactly once per run. Trees carry
/// global vertex ids and **compare-form** weights (the cache is internal to
/// the merge path; emission happens on pair-tree return).
pub struct LocalMstCache {
    pub trees: Vec<Vec<Edge>>,
    /// distance evaluations spent building the cache:
    /// `Σ_k |S_k|(|S_k|-1)/2`
    pub evals: u64,
    /// wall time spent building the cache (serial builds only; pooled
    /// builds are timed by the engine)
    pub build_time: Duration,
}

impl LocalMstCache {
    /// Build the cache on the calling thread (the serial path; the pooled
    /// engine builds it through the worker pool instead).
    pub fn build_serial(ds: &Dataset, ctx: &BipartiteCtx, parts: &[Vec<u32>]) -> Self {
        let t = Instant::now();
        let counter = CountingMetric::new(ctx.kind);
        let trees = parts
            .iter()
            .map(|ids| subset_mst(ds.as_slice(), ds.d, ctx.block.as_ref(), &ctx.aux, &counter, ids))
            .collect();
        Self { trees, evals: counter.evals(), build_time: t.elapsed() }
    }
}

/// One subset's packed operand for blocked `S_i × S_j` distance panels: the
/// subset's rows gathered contiguously at a **lane-padded stride**
/// ([`simd::padded_stride`], pad region zero, so the SIMD micro-kernels run
/// whole-chunk loads), plus the matching slice of the per-row auxiliary
/// values (norms). Copies of the prepared full-matrix values, so panel
/// arithmetic stays bit-identical to the row path.
pub struct SubsetPanel {
    pub data: Vec<f32>,
    pub aux: Vec<f32>,
    pub rows: usize,
    /// floats per packed row (`≥ d`, a multiple of [`simd::LANES`])
    pub stride: usize,
}

impl SubsetPanel {
    pub fn build(ds: &Dataset, ctx: &BipartiteCtx, ids: &[u32]) -> Self {
        let d = ds.d;
        let src = ds.as_slice();
        let stride = simd::padded_stride(d);
        let mut data = vec![0.0f32; ids.len() * stride];
        for (k, &g) in ids.iter().enumerate() {
            let g = g as usize;
            data[k * stride..k * stride + d].copy_from_slice(&src[g * d..(g + 1) * d]);
        }
        let aux: Vec<f32> = if ctx.aux.is_empty() {
            Vec::new()
        } else {
            ids.iter().map(|&g| ctx.aux[g as usize]).collect()
        };
        Self { data, aux, rows: ids.len(), stride }
    }

    /// Pack `n` already-contiguous rows (stride `d`) into a padded panel —
    /// the remote worker's path, where the subset's vectors arrive as one
    /// resident matrix rather than an id-gather.
    pub fn from_rows(rows: &[f32], n: usize, d: usize, aux: &[f32]) -> Self {
        let (data, stride) = simd::pad_rows(rows, n, d);
        Self { data, aux: aux.to_vec(), rows: n, stride }
    }
}

/// A small keyed LRU (most recently used last) with hit/miss counters —
/// **the** panel-reuse policy, shared by the in-process [`PanelCache`]
/// (values are built [`SubsetPanel`]s) and the remote worker's stats-only
/// mirror (`KeyedLru<()>`, the subset data is already resident there), so
/// panel metrics mean the same thing on both transports.
pub struct KeyedLru<V> {
    /// LRU order: most recently used last
    slots: Vec<(u32, V)>,
    cap: usize,
    pub hits: u64,
    pub misses: u64,
}

impl<V> KeyedLru<V> {
    /// `cap` is clamped to ≥ 2 so both entries of one pair job always fit.
    pub fn new(cap: usize) -> Self {
        Self { slots: Vec::new(), cap: cap.max(2), hits: 0, misses: 0 }
    }

    /// Probe `key`: a hit moves it to most-recent, a miss builds the value
    /// (evicting the least-recent entry at capacity). Returns whether it
    /// hit.
    pub fn ensure_with(&mut self, key: u32, build: impl FnOnce() -> V) -> bool {
        if let Some(pos) = self.slots.iter().position(|(k, _)| *k == key) {
            self.hits += 1;
            let entry = self.slots.remove(pos);
            self.slots.push(entry);
            true
        } else {
            self.misses += 1;
            if self.slots.len() == self.cap {
                self.slots.remove(0);
            }
            self.slots.push((key, build()));
            false
        }
    }

    pub fn get(&self, key: u32) -> Option<&V> {
        self.slots.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }
}

/// Panel-cache capacity, identical on both transports.
pub const PANEL_CACHE_CAP: usize = 4;

/// A small per-worker LRU of [`SubsetPanel`]s keyed by subset id. Affinity
/// routing sends consecutive jobs sharing a subset to the same worker, so a
/// handful of slots is enough for high hit rates — the anchor subset stays
/// resident while its partners rotate through. The replacement policy and
/// counters live in [`KeyedLru`].
pub struct PanelCache {
    lru: KeyedLru<SubsetPanel>,
}

impl PanelCache {
    /// `cap` is clamped to ≥ 2 so both panels of one pair job always fit.
    pub fn new(cap: usize) -> Self {
        Self { lru: KeyedLru::new(cap) }
    }

    /// Fetch-or-build both panels of a pair job (`i != j`). With `cap ≥ 2`
    /// the second probe can never evict the first (it is most recent).
    pub fn pair(
        &mut self,
        ds: &Dataset,
        ctx: &BipartiteCtx,
        i: u32,
        si: &[u32],
        j: u32,
        sj: &[u32],
    ) -> (&SubsetPanel, &SubsetPanel) {
        debug_assert_ne!(i, j);
        self.lru.ensure_with(i, || SubsetPanel::build(ds, ctx, si));
        self.lru.ensure_with(j, || SubsetPanel::build(ds, ctx, sj));
        let pi = self.lru.get(i).expect("just ensured");
        let pj = self.lru.get(j).expect("just ensured");
        (pi, pj)
    }

    /// `(hits, misses)` across all probes.
    pub fn stats(&self) -> (u64, u64) {
        (self.lru.hits, self.lru.misses)
    }
}

/// The bipartite-merge pair kernel: filtered Prim over
/// `MST(S_i) ∪ MST(S_j) ∪ bipartite(S_i × S_j)` with cached local MSTs.
///
/// The bipartite block is computed **as one `S_i × S_j` panel product**
/// through [`DistanceBlock::panel_block`] — the Gram/dot path over two
/// packed panels served from a per-worker [`PanelCache`] — instead of
/// row-at-a-time queries inside the Prim loop. Same `|S_i|·|S_j|`
/// evaluation count, same bit-identical values, far better locality: jobs
/// sharing a subset reuse its packed rows and precomputed norms.
pub struct BipartitePairSolver<'a> {
    ds: &'a Dataset,
    ctx: &'a BipartiteCtx,
    cache: &'a LocalMstCache,
    counter: CountingMetric,
    panels: PanelCache,
    /// reusable `|S_i| × |S_j|` distance-block buffer
    blk: Vec<f32>,
    /// measured panel work (FLOPs, wall time, thread plan, ISA)
    perf: PanelPerf,
    /// per-solver PJRT engine for XLA panel routing (PJRT handles are not
    /// `Send`, so the engine lives with the solver's thread)
    #[cfg(feature = "backend-xla")]
    xla: Option<crate::runtime::pairwise::XlaPairwise>,
}

impl<'a> BipartitePairSolver<'a> {
    pub fn new(ds: &'a Dataset, ctx: &'a BipartiteCtx, cache: &'a LocalMstCache) -> Self {
        Self {
            ds,
            ctx,
            cache,
            counter: CountingMetric::new(ctx.kind),
            panels: PanelCache::new(PANEL_CACHE_CAP),
            blk: Vec::new(),
            perf: PanelPerf::default(),
            #[cfg(feature = "backend-xla")]
            xla: ctx.xla_panels.as_deref().and_then(|dir| {
                crate::runtime::engine::Engine::load(dir)
                    .ok()
                    .map(crate::runtime::pairwise::XlaPairwise::new)
            }),
        }
    }
}

/// Route one sqeuclid/euclid bipartite block through the AOT XLA pairwise
/// artifact. `false` means "not taken" (unsupported metric or a runtime
/// error) — the caller falls back to the SIMD panel path, gracefully.
#[cfg(feature = "backend-xla")]
fn xla_panel_block(
    xla: &crate::runtime::pairwise::XlaPairwise,
    kind: MetricKind,
    pi: &SubsetPanel,
    pj: &SubsetPanel,
    d: usize,
    out: &mut [f32],
) -> bool {
    if !matches!(kind, MetricKind::SqEuclid | MetricKind::Euclid) {
        return false;
    }
    match xla.bipartite_block(&pi.data, pi.rows, pi.stride, &pj.data, pj.rows, pj.stride, d) {
        Ok(blk) => {
            out.copy_from_slice(&blk);
            true
        }
        Err(_) => false,
    }
}

impl PairSolver for BipartitePairSolver<'_> {
    fn solve(&mut self, plan: &ExecPlan, job: &PairJob) -> Vec<Edge> {
        if job.i == job.j {
            // Degenerate |P| = 1: the cached local MST *is* the pair tree.
            return emit_tree(self.ctx, &self.cache.trees[job.i as usize]);
        }
        let si = &plan.parts[job.i as usize];
        let sj = &plan.parts[job.j as usize];
        let (pi, pj) = self.panels.pair(self.ds, self.ctx, job.i, si, job.j, sj);
        let (m, n, d) = (si.len(), sj.len(), self.ds.d);
        self.blk.resize(m * n, 0.0);
        let panel_t = Instant::now();
        #[allow(unused_mut)]
        let mut routed_to_xla = false;
        #[cfg(feature = "backend-xla")]
        if let Some(xla) = &self.xla {
            routed_to_xla = xla_panel_block(xla, self.ctx.kind, pi, pj, d, &mut self.blk);
        }
        if !routed_to_xla {
            self.ctx.block.panel_block(
                &pi.data,
                &pi.aux,
                m,
                &pj.data,
                &pj.aux,
                n,
                d,
                pi.stride,
                &mut self.blk,
            );
        }
        self.perf.time += panel_t.elapsed();
        self.perf.flops += simd::panel_flops(self.ctx.kind, m, n, d);
        self.perf.threads =
            self.perf.threads.max(simd::planned_threads(self.ctx.panel, m, n, d) as u32);
        self.perf.isa = self.ctx.panel.isa.wire_code();
        self.counter.add_external((m * n) as u64);
        let tree = bipartite_filtered_prim_blocked(
            si,
            sj,
            &self.cache.trees[job.i as usize],
            &self.cache.trees[job.j as usize],
            &self.blk,
        );
        emit_tree(self.ctx, &tree)
    }

    fn dist_evals(&self) -> u64 {
        self.counter.evals()
    }

    fn panel_stats(&self) -> (u64, u64) {
        self.panels.stats()
    }

    fn panel_perf(&self) -> PanelPerf {
        self.perf
    }
}

/// Index into `active` of the vertex with the strict-minimum frontier edge
/// under the canonical `(w, min(id, to), max(id, to))` order — the Prim pick
/// shared by [`subset_mst`] and [`bipartite_filtered_prim`], factored out so
/// the exactness-critical tie-break lives in exactly one place.
///
/// `active` holds positions into `ids`; `best_w`/`best_to` are indexed by
/// position, with `best_to` carrying the *global* id of the tree endpoint.
/// `active` must be non-empty.
fn pick_min(active: &[u32], ids: &[u32], best_w: &[f32], best_to: &[u32]) -> usize {
    debug_assert!(!active.is_empty());
    let mut pick_at = 0usize;
    for k in 1..active.len() {
        let p = active[k] as usize;
        let q = active[pick_at] as usize;
        let (gp, gq) = (ids[p], ids[q]);
        if edge_cmp(
            best_w[p],
            best_to[p].min(gp),
            best_to[p].max(gp),
            best_w[q],
            best_to[q].min(gq),
            best_to[q].max(gq),
        ) == Ordering::Less
        {
            pick_at = k;
        }
    }
    pick_at
}

/// Map a compare-form tree to emission form (`sqrt` weights for Euclid).
pub fn emit_tree(ctx: &BipartiteCtx, tree: &[Edge]) -> Vec<Edge> {
    if ctx.sqrt_at_emit {
        tree.iter().map(|e| Edge::new(e.u, e.v, e.w.sqrt())).collect()
    } else {
        tree.to_vec()
    }
}

/// Canonical MST of the complete graph over the subset `ids` (ascending
/// global ids), using blocked distance rows over the **full** point matrix
/// (no gather). Edges carry global endpoints and compare-form weights.
///
/// This is the blocked dense Prim restructured to run in place: identical
/// arithmetic (same rows, same [`DistanceBlock`] dot/norm path) and the
/// identical strict `(w, u, v)` tie-break in global ids — the subset's
/// ascending-id order makes local and global strict order agree, exactly as
/// the gathered-and-sorted dense path does.
pub fn subset_mst(
    data: &[f32],
    d: usize,
    block: &dyn DistanceBlock,
    aux: &[f32],
    counter: &CountingMetric,
    ids: &[u32],
) -> Vec<Edge> {
    let m = ids.len();
    let mut tree = Vec::with_capacity(m.saturating_sub(1));
    if m <= 1 {
        return tree;
    }
    let mut best_w = vec![f32::INFINITY; m];
    let mut best_to = vec![0u32; m]; // global id of the tree endpoint
    // positions into `ids` not yet admitted
    let mut active: Vec<u32> = (1..m as u32).collect();
    let mut js_buf: Vec<u32> = Vec::with_capacity(m);
    let mut row = vec![0.0f32; m];

    // Initial row: root ids[0] to everything else.
    js_buf.clear();
    js_buf.extend(active.iter().map(|&p| ids[p as usize]));
    block.row(data, d, aux, ids[0] as usize, &js_buf, &mut row);
    counter.add_external(active.len() as u64);
    for (k, &p) in active.iter().enumerate() {
        best_w[p as usize] = row[k];
        best_to[p as usize] = ids[0];
    }

    for _round in 1..m {
        let pick_at = pick_min(&active, ids, &best_w, &best_to);
        let pick = active.swap_remove(pick_at) as usize;
        let gpick = ids[pick];
        tree.push(Edge::new(best_to[pick], gpick, best_w[pick]));
        if active.is_empty() {
            break;
        }
        js_buf.clear();
        js_buf.extend(active.iter().map(|&p| ids[p as usize]));
        block.row(data, d, aux, gpick as usize, &js_buf, &mut row);
        counter.add_external(active.len() as u64);
        for (k, &p) in active.iter().enumerate() {
            let p = p as usize;
            let gp = ids[p];
            let w = row[k];
            if edge_cmp(
                w,
                gpick.min(gp),
                gpick.max(gp),
                best_w[p],
                best_to[p].min(gp),
                best_to[p].max(gp),
            ) == Ordering::Less
            {
                best_w[p] = w;
                best_to[p] = gpick;
            }
        }
    }
    tree
}

/// [`subset_mst`] over a *gathered* subset matrix: `points` holds the
/// subset's rows packed in ascending-global-id order, `global_ids[k]` is row
/// `k`'s global id. Used by the remote worker, which holds only the subsets
/// it was shipped — never the full matrix.
///
/// Bit-identical to [`subset_mst`] over the full matrix: per-pair distance
/// arithmetic is independent of the surrounding rows (same [`DistanceBlock`]
/// dot/norm path over the same two rows and per-row aux values), and the
/// ascending-id packing makes local index order a strictly monotone map of
/// global id order, so every `(w, u, v)` tie-break compares identically.
/// Returned edges carry global endpoints, compare-form weights.
pub fn subset_mst_gathered(
    points: &Dataset,
    block: &dyn DistanceBlock,
    aux: &[f32],
    counter: &CountingMetric,
    global_ids: &[u32],
) -> Vec<Edge> {
    debug_assert_eq!(points.n, global_ids.len());
    debug_assert!(global_ids.windows(2).all(|w| w[0] < w[1]), "ids must ascend");
    let local: Vec<u32> = (0..points.n as u32).collect();
    let tree = subset_mst(points.as_slice(), points.d, block, aux, counter, &local);
    tree.iter()
        .map(|e| Edge::new(global_ids[e.u as usize], global_ids[e.v as usize], e.w))
        .collect()
}

/// Filtered Prim over the sparse pair graph
/// `G' = MST(S_i) ∪ MST(S_j) ∪ bipartite(S_i × S_j)`.
///
/// Same-side non-tree edges are never touched (weight +∞ in `G'`); each
/// admitted vertex relaxes one blocked distance row against the *active
/// cross-side* vertices only, so every bipartite pair is evaluated exactly
/// once: `|S_i|·|S_j|` evaluations per job, and per-job memory is `O(m)`
/// plus one distance row — not the `(|S_i|+|S_j|)²` evaluation volume of
/// the dense kernel. Input trees and the returned tree are in compare-form
/// weights with global ids.
pub fn bipartite_filtered_prim(
    ds: &Dataset,
    ctx: &BipartiteCtx,
    si: &[u32],
    sj: &[u32],
    tree_i: &[Edge],
    tree_j: &[Edge],
    counter: &CountingMetric,
) -> Vec<Edge> {
    let ids = merge_sorted_ids(si, sj);
    let m = ids.len();
    let mut tree = Vec::with_capacity(m.saturating_sub(1));
    if m <= 1 {
        return tree;
    }
    let pos_of = |g: u32| -> usize {
        ids.binary_search(&g).expect("tree endpoint outside the pair union")
    };
    // side flag per position: true = S_i
    let in_side_i: Vec<bool> = ids.iter().map(|g| si.binary_search(g).is_ok()).collect();
    // adjacency of the two local trees, in positions
    let mut adj: Vec<Vec<(u32, f32)>> = vec![Vec::new(); m];
    for e in tree_i.iter().chain(tree_j.iter()) {
        let (pu, pv) = (pos_of(e.u), pos_of(e.v));
        adj[pu].push((pv as u32, e.w));
        adj[pv].push((pu as u32, e.w));
    }

    let data = ds.as_slice();
    let mut best_w = vec![f32::INFINITY; m];
    // global id of the tree endpoint; u32::MAX = no G'-edge seen yet
    let mut best_to = vec![u32::MAX; m];
    let mut in_tree = vec![false; m];
    let mut active: Vec<u32> = (1..m as u32).collect();
    let mut cross_pos: Vec<u32> = Vec::with_capacity(m);
    let mut cross_ids: Vec<u32> = Vec::with_capacity(m);
    let mut row = vec![0.0f32; m];

    in_tree[0] = true;
    relax_from(
        0, &ids, &in_side_i, &adj, data, ds.d, ctx, counter, &active, &in_tree, &mut best_w,
        &mut best_to, &mut cross_pos, &mut cross_ids, &mut row,
    );

    for _round in 1..m {
        let pick_at = pick_min(&active, &ids, &best_w, &best_to);
        let pick = active.swap_remove(pick_at) as usize;
        debug_assert!(best_w[pick].is_finite(), "G' is connected; frontier must be finite");
        in_tree[pick] = true;
        tree.push(Edge::new(best_to[pick], ids[pick], best_w[pick]));
        if active.is_empty() {
            break;
        }
        relax_from(
            pick, &ids, &in_side_i, &adj, data, ds.d, ctx, counter, &active, &in_tree,
            &mut best_w, &mut best_to, &mut cross_pos, &mut cross_ids, &mut row,
        );
    }
    tree
}

/// One Prim relaxation round in `G'`: a bipartite distance row against the
/// active cross-side vertices, plus the pivot's incident local-tree edges.
fn relax_from(
    pivot: usize,
    ids: &[u32],
    in_side_i: &[bool],
    adj: &[Vec<(u32, f32)>],
    data: &[f32],
    d: usize,
    ctx: &BipartiteCtx,
    counter: &CountingMetric,
    active: &[u32],
    in_tree: &[bool],
    best_w: &mut [f32],
    best_to: &mut [u32],
    cross_pos: &mut Vec<u32>,
    cross_ids: &mut Vec<u32>,
    row: &mut [f32],
) {
    let gpivot = ids[pivot];
    let pivot_in_i = in_side_i[pivot];
    cross_pos.clear();
    cross_ids.clear();
    for &p in active {
        if in_side_i[p as usize] != pivot_in_i {
            cross_pos.push(p);
            cross_ids.push(ids[p as usize]);
        }
    }
    if !cross_ids.is_empty() {
        ctx.block.row(data, d, &ctx.aux, gpivot as usize, cross_ids, row);
        counter.add_external(cross_ids.len() as u64);
        for (k, &p) in cross_pos.iter().enumerate() {
            let p = p as usize;
            let g = ids[p];
            let w = row[k];
            if edge_cmp(
                w,
                gpivot.min(g),
                gpivot.max(g),
                best_w[p],
                best_to[p].min(g),
                best_to[p].max(g),
            ) == Ordering::Less
            {
                best_w[p] = w;
                best_to[p] = gpivot;
            }
        }
    }
    for &(q, w) in &adj[pivot] {
        let q = q as usize;
        if in_tree[q] {
            continue;
        }
        let g = ids[q];
        if edge_cmp(
            w,
            gpivot.min(g),
            gpivot.max(g),
            best_w[q],
            best_to[q].min(g),
            best_to[q].max(g),
        ) == Ordering::Less
        {
            best_w[q] = w;
            best_to[q] = gpivot;
        }
    }
}

/// Filtered Prim over the sparse pair graph, consuming a **precomputed**
/// row-major `|S_i| × |S_j|` bipartite distance block (compare-form values,
/// e.g. from [`DistanceBlock::panel_block`] over two [`SubsetPanel`]s)
/// instead of issuing row queries per admitted vertex.
///
/// Returns the identical tree as [`bipartite_filtered_prim`] when `blk`
/// holds the same per-pair values: both run the same relaxations under the
/// same strict `(w, u, v)` order — only where the distances come from
/// differs. Distance evaluations are accounted by whoever computed `blk`.
pub fn bipartite_filtered_prim_blocked(
    si: &[u32],
    sj: &[u32],
    tree_i: &[Edge],
    tree_j: &[Edge],
    blk: &[f32],
) -> Vec<Edge> {
    debug_assert_eq!(blk.len(), si.len() * sj.len());
    let ids = merge_sorted_ids(si, sj);
    let m = ids.len();
    let nj = sj.len();
    let mut tree = Vec::with_capacity(m.saturating_sub(1));
    if m <= 1 {
        return tree;
    }
    let pos_of = |g: u32| -> usize {
        ids.binary_search(&g).expect("tree endpoint outside the pair union")
    };
    // per position: which side, and the rank within that side (the row /
    // column index into `blk`)
    let mut in_side_i = vec![false; m];
    let mut rank = vec![0u32; m];
    {
        let (mut a, mut b) = (0usize, 0usize);
        for (pos, &g) in ids.iter().enumerate() {
            if a < si.len() && si[a] == g {
                in_side_i[pos] = true;
                rank[pos] = a as u32;
                a += 1;
            } else {
                debug_assert_eq!(sj[b], g, "merged ids must interleave si and sj");
                rank[pos] = b as u32;
                b += 1;
            }
        }
    }
    // adjacency of the two local trees, in positions
    let mut adj: Vec<Vec<(u32, f32)>> = vec![Vec::new(); m];
    for e in tree_i.iter().chain(tree_j.iter()) {
        let (pu, pv) = (pos_of(e.u), pos_of(e.v));
        adj[pu].push((pv as u32, e.w));
        adj[pv].push((pu as u32, e.w));
    }

    let mut best_w = vec![f32::INFINITY; m];
    let mut best_to = vec![u32::MAX; m];
    let mut in_tree = vec![false; m];
    let mut active: Vec<u32> = (1..m as u32).collect();

    in_tree[0] = true;
    relax_from_blocked(
        0, &ids, &in_side_i, &rank, nj, blk, &adj, &active, &in_tree, &mut best_w, &mut best_to,
    );

    for _round in 1..m {
        let pick_at = pick_min(&active, &ids, &best_w, &best_to);
        let pick = active.swap_remove(pick_at) as usize;
        debug_assert!(best_w[pick].is_finite(), "G' is connected; frontier must be finite");
        in_tree[pick] = true;
        tree.push(Edge::new(best_to[pick], ids[pick], best_w[pick]));
        if active.is_empty() {
            break;
        }
        relax_from_blocked(
            pick, &ids, &in_side_i, &rank, nj, blk, &adj, &active, &in_tree, &mut best_w,
            &mut best_to,
        );
    }
    tree
}

/// One Prim relaxation round in `G'`, reading the precomputed bipartite
/// block for cross-side candidates and the pivot's local-tree edges.
fn relax_from_blocked(
    pivot: usize,
    ids: &[u32],
    in_side_i: &[bool],
    rank: &[u32],
    nj: usize,
    blk: &[f32],
    adj: &[Vec<(u32, f32)>],
    active: &[u32],
    in_tree: &[bool],
    best_w: &mut [f32],
    best_to: &mut [u32],
) {
    let gpivot = ids[pivot];
    let pivot_in_i = in_side_i[pivot];
    for &p in active {
        let p = p as usize;
        if in_side_i[p] == pivot_in_i {
            continue;
        }
        let w = if pivot_in_i {
            blk[rank[pivot] as usize * nj + rank[p] as usize]
        } else {
            blk[rank[p] as usize * nj + rank[pivot] as usize]
        };
        let g = ids[p];
        if edge_cmp(
            w,
            gpivot.min(g),
            gpivot.max(g),
            best_w[p],
            best_to[p].min(g),
            best_to[p].max(g),
        ) == Ordering::Less
        {
            best_w[p] = w;
            best_to[p] = gpivot;
        }
    }
    for &(q, w) in &adj[pivot] {
        let q = q as usize;
        if in_tree[q] {
            continue;
        }
        let g = ids[q];
        if edge_cmp(
            w,
            gpivot.min(g),
            gpivot.max(g),
            best_w[q],
            best_to[q].min(g),
            best_to[q].max(g),
        ) == Ordering::Less
        {
            best_w[q] = w;
            best_to[q] = gpivot;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dense::{DenseMst, PrimDense};
    use crate::mst::normalize_tree;
    use crate::util::prng::Pcg64;

    fn int_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(19) as f32 - 9.0).collect();
        Dataset::new(n, d, data)
    }

    fn float_dataset(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
        Dataset::new(n, d, data)
    }

    #[test]
    fn subset_mst_matches_dense_prim_on_gathered_subset() {
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            // float data: the arithmetic must be bit-identical, not just close
            let ds = float_dataset(11, 40, 7);
            let ctx = BipartiteCtx::new(&ds, kind);
            let ids: Vec<u32> = (0..40u32).filter(|i| i % 3 != 1).collect();
            let counter = CountingMetric::new(kind);
            let sub =
                subset_mst(ds.as_slice(), ds.d, ctx.block.as_ref(), &ctx.aux, &counter, &ids);
            let m = ids.len() as u64;
            assert_eq!(counter.evals(), m * (m - 1) / 2, "{kind:?} eval count");

            let gathered = ds.gather(&ids);
            let dense = PrimDense::new(kind).mst(&gathered);
            let dense_global: Vec<Edge> = dense
                .iter()
                .map(|e| Edge::new(ids[e.u as usize], ids[e.v as usize], e.w))
                .collect();
            // compare in emission form: sqrt of the identical squared value
            // is bit-exact, squaring the sqrt is not
            assert_eq!(
                normalize_tree(&dense_global),
                normalize_tree(&emit_tree(&ctx, &sub)),
                "{kind:?}"
            );
        }
    }

    /// The remote worker computes subset MSTs over *gathered* rows (it never
    /// holds the full matrix); the result must be bit-identical to the
    /// full-matrix path across every metric, on float data.
    #[test]
    fn subset_mst_gathered_bit_identical_to_full_matrix() {
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            let ds = float_dataset(19, 44, 5);
            let ctx = BipartiteCtx::new(&ds, kind);
            let ids: Vec<u32> = (0..44u32).filter(|i| i % 4 != 1).collect();
            let counter = CountingMetric::new(kind);
            let full =
                subset_mst(ds.as_slice(), ds.d, ctx.block.as_ref(), &ctx.aux, &counter, &ids);

            let gathered = ds.gather(&ids);
            let aux = ctx.block.prepare(gathered.as_slice(), gathered.n, gathered.d);
            let counter2 = CountingMetric::new(kind);
            let got =
                subset_mst_gathered(&gathered, ctx.block.as_ref(), &aux, &counter2, &ids);
            assert_eq!(full, got, "{kind:?}: gathered path must be bit-identical");
            assert_eq!(counter.evals(), counter2.evals());
        }
    }

    #[test]
    fn filtered_prim_matches_dense_pair_kernel() {
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            let ds = float_dataset(12, 60, 5);
            let ctx = BipartiteCtx::new(&ds, kind);
            let si: Vec<u32> = (0..60u32).filter(|i| i % 2 == 0).collect();
            let sj: Vec<u32> = (0..60u32).filter(|i| i % 2 == 1).collect();
            let counter = CountingMetric::new(kind);
            let blk = ctx.block.as_ref();
            let ti = subset_mst(ds.as_slice(), ds.d, blk, &ctx.aux, &counter, &si);
            let tj = subset_mst(ds.as_slice(), ds.d, blk, &ctx.aux, &counter, &sj);
            counter.reset();
            let merged = bipartite_filtered_prim(&ds, &ctx, &si, &sj, &ti, &tj, &counter);
            assert_eq!(
                counter.evals(),
                (si.len() * sj.len()) as u64,
                "{kind:?}: exactly |Si|·|Sj| bipartite evaluations"
            );

            let dense = run_pair(&ds, &si, &sj, &PrimDense::new(kind));
            assert_eq!(
                normalize_tree(&dense),
                normalize_tree(&emit_tree(&ctx, &merged)),
                "{kind:?}: filtered Prim == dense pair kernel"
            );
        }
    }

    #[test]
    fn filtered_prim_uneven_and_tiny_sides() {
        let ds = int_dataset(13, 30, 4);
        let ctx = BipartiteCtx::new(&ds, MetricKind::SqEuclid);
        for (si_len, sj_len) in [(1usize, 29usize), (2, 5), (29, 1)] {
            let si: Vec<u32> = (0..si_len as u32).collect();
            let sj: Vec<u32> = (si_len as u32..(si_len + sj_len) as u32).collect();
            let counter = CountingMetric::new(MetricKind::SqEuclid);
            let blk = ctx.block.as_ref();
            let ti = subset_mst(ds.as_slice(), ds.d, blk, &ctx.aux, &counter, &si);
            let tj = subset_mst(ds.as_slice(), ds.d, blk, &ctx.aux, &counter, &sj);
            let merged = bipartite_filtered_prim(&ds, &ctx, &si, &sj, &ti, &tj, &counter);
            let dense = run_pair(&ds, &si, &sj, &PrimDense::sq_euclid());
            assert_eq!(
                normalize_tree(&dense),
                normalize_tree(&merged),
                "sides {si_len}/{sj_len}"
            );
        }
    }

    /// The panel-block path through the blocked filtered Prim must return
    /// the bit-identical tree as the row-at-a-time oracle, on float data,
    /// across every metric.
    #[test]
    fn blocked_filtered_prim_bit_identical_to_row_path() {
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            let ds = float_dataset(16, 54, 6);
            let ctx = BipartiteCtx::new(&ds, kind);
            let si: Vec<u32> = (0..54u32).filter(|i| i % 3 != 2).collect();
            let sj: Vec<u32> = (0..54u32).filter(|i| i % 3 == 2).collect();
            let counter = CountingMetric::new(kind);
            let blk = ctx.block.as_ref();
            let ti = subset_mst(ds.as_slice(), ds.d, blk, &ctx.aux, &counter, &si);
            let tj = subset_mst(ds.as_slice(), ds.d, blk, &ctx.aux, &counter, &sj);
            let row_path = bipartite_filtered_prim(&ds, &ctx, &si, &sj, &ti, &tj, &counter);

            let pi = SubsetPanel::build(&ds, &ctx, &si);
            let pj = SubsetPanel::build(&ds, &ctx, &sj);
            let mut tile = vec![0.0f32; si.len() * sj.len()];
            ctx.block.panel_block(
                &pi.data,
                &pi.aux,
                si.len(),
                &pj.data,
                &pj.aux,
                sj.len(),
                ds.d,
                pi.stride,
                &mut tile,
            );
            let panel_path = bipartite_filtered_prim_blocked(&si, &sj, &ti, &tj, &tile);
            assert_eq!(row_path, panel_path, "{kind:?}: trees must be bit-identical");
        }
    }

    #[test]
    fn panel_cache_lru_hits_and_eviction() {
        let ds = int_dataset(17, 40, 3);
        let ctx = BipartiteCtx::new(&ds, MetricKind::SqEuclid);
        let subsets: Vec<Vec<u32>> =
            (0..5u32).map(|k| (k * 8..(k + 1) * 8).collect()).collect();
        let mut cache = PanelCache::new(2);
        // (0,1): two misses
        cache.pair(&ds, &ctx, 0, &subsets[0], 1, &subsets[1]);
        assert_eq!(cache.stats(), (0, 2));
        // (0,2): hit on 0; miss on 2 evicts the LRU entry (1)
        cache.pair(&ds, &ctx, 0, &subsets[0], 2, &subsets[2]);
        assert_eq!(cache.stats(), (1, 3));
        // (0,2) again: both hit
        cache.pair(&ds, &ctx, 0, &subsets[0], 2, &subsets[2]);
        assert_eq!(cache.stats(), (3, 3));
        // (1,3): 1 was evicted — both miss
        cache.pair(&ds, &ctx, 1, &subsets[1], 3, &subsets[3]);
        assert_eq!(cache.stats(), (3, 5));
        // panels carry the right geometry (rows padded to the lane multiple)
        let (p1, p3) = cache.pair(&ds, &ctx, 1, &subsets[1], 3, &subsets[3]);
        assert_eq!(p1.rows, 8);
        assert_eq!(p3.stride, crate::geometry::simd::padded_stride(ds.d));
        assert_eq!(p3.data.len(), 8 * p3.stride);
        assert_eq!(p1.aux.len(), 8, "sq-euclid panels carry norms");
    }

    #[test]
    fn solver_panel_stats_track_subset_reuse() {
        // Jobs (0,1), (0,2), (1,2) on one solver: 6 ensures, 3 distinct
        // subsets -> exactly 3 misses, 3 hits with an adequate cap.
        let ds = int_dataset(18, 36, 4);
        let plan = ExecPlan::new(&ds, 3, crate::decomp::PartitionStrategy::Block, 0);
        let ctx = BipartiteCtx::new(&ds, MetricKind::SqEuclid);
        let cache = LocalMstCache::build_serial(&ds, &ctx, &plan.parts);
        let mut solver = BipartitePairSolver::new(&ds, &ctx, &cache);
        for job in &plan.jobs {
            solver.solve(&plan, job);
        }
        assert_eq!(solver.panel_stats(), (3, 3));
    }

    #[test]
    fn local_cache_counts_internal_work_once() {
        let ds = int_dataset(14, 48, 3);
        let ctx = BipartiteCtx::new(&ds, MetricKind::SqEuclid);
        let parts: Vec<Vec<u32>> = vec![
            (0..16u32).collect(),
            (16..32u32).collect(),
            (32..48u32).collect(),
        ];
        let cache = LocalMstCache::build_serial(&ds, &ctx, &parts);
        assert_eq!(cache.trees.len(), 3);
        assert_eq!(cache.evals, 3 * (16 * 15 / 2), "Σ_k |S_k|(|S_k|-1)/2");
        for t in &cache.trees {
            assert_eq!(t.len(), 15);
        }
    }

    #[test]
    fn emit_tree_sqrt_only_for_euclid() {
        let ds = int_dataset(15, 8, 2);
        let sq = BipartiteCtx::new(&ds, MetricKind::SqEuclid);
        let eu = BipartiteCtx::new(&ds, MetricKind::Euclid);
        let t = vec![Edge::new(0, 1, 9.0)];
        assert_eq!(emit_tree(&sq, &t)[0].w, 9.0);
        assert_eq!(emit_tree(&eu, &t)[0].w, 3.0);
    }
}
