//! The execution plan: partition + pair schedule + per-job cost estimates.
//!
//! One plan serves every execution mode (serial, pooled, distributed): it
//! fixes *what* gets computed — the partition subsets and the pair jobs —
//! while the engine decides *where* and *in what order*. The degenerate
//! `|P| = 1` case is folded in as a single self-pair job (`i == j`), so the
//! engines have no special cases.

use crate::data::Dataset;
use crate::decomp::{partition_indices, PairJob, PairSchedule, PartitionStrategy};

/// Partition, schedule, and cost model for one decomposed-MST execution.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    /// the partition subsets, each sorted ascending by global id
    pub parts: Vec<Vec<u32>>,
    /// pair jobs in the paper's schedule order; for `|P| = 1` a single
    /// degenerate self-pair job `{id: 0, i: 0, j: 0}`
    pub jobs: Vec<PairJob>,
    /// job indices sorted by descending cost estimate (LPT deal order);
    /// ties keep schedule order
    pub lpt_order: Vec<usize>,
}

impl ExecPlan {
    /// Partition `ds` and lay out the pair jobs with their cost estimates.
    pub fn new(ds: &Dataset, parts: usize, strategy: PartitionStrategy, seed: u64) -> Self {
        let part_ids = partition_indices(ds, parts, strategy, seed);
        let jobs: Vec<PairJob> = if parts == 1 {
            vec![PairJob { id: 0, i: 0, j: 0 }]
        } else {
            PairSchedule::new(parts).jobs
        };
        let costs: Vec<u64> = jobs.iter().map(|j| job_cost(&part_ids, j)).collect();
        let mut lpt_order: Vec<usize> = (0..jobs.len()).collect();
        lpt_order.sort_by(|&a, &b| costs[b].cmp(&costs[a]).then(a.cmp(&b)));
        Self { parts: part_ids, jobs, lpt_order }
    }

    /// Number of pair jobs (≥ 1; the degenerate single-subset job counts).
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// The cost estimate used to deal `job`: `|S_i|·|S_j|` — the size of the
    /// bipartite distance block, which dominates both pair kernels' work.
    pub fn job_cost(&self, job: &PairJob) -> u64 {
        job_cost(&self.parts, job)
    }

    /// Sizes of the partition subsets.
    pub fn part_sizes(&self) -> Vec<usize> {
        self.parts.iter().map(|p| p.len()).collect()
    }
}

fn job_cost(parts: &[Vec<u32>], job: &PairJob) -> u64 {
    let si = parts[job.i as usize].len() as u64;
    if job.i == job.j {
        si * si
    } else {
        si * parts[job.j as usize].len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::uniform;
    use crate::util::prng::Pcg64;

    #[test]
    fn degenerate_single_part_has_one_self_job() {
        let ds = uniform(20, 3, 1.0, Pcg64::seeded(1));
        let plan = ExecPlan::new(&ds, 1, PartitionStrategy::Block, 0);
        assert_eq!(plan.n_jobs(), 1);
        assert_eq!((plan.jobs[0].i, plan.jobs[0].j), (0, 0));
        assert_eq!(plan.job_cost(&plan.jobs[0]), 400);
    }

    #[test]
    fn lpt_order_is_cost_descending_and_complete() {
        let ds = uniform(50, 2, 1.0, Pcg64::seeded(2));
        let plan = ExecPlan::new(&ds, 5, PartitionStrategy::Block, 0);
        assert_eq!(plan.n_jobs(), 10);
        assert_eq!(plan.lpt_order.len(), 10);
        let mut seen = vec![false; 10];
        let mut prev = u64::MAX;
        for &k in &plan.lpt_order {
            assert!(!seen[k], "job {k} dealt twice");
            seen[k] = true;
            let c = plan.job_cost(&plan.jobs[k]);
            assert!(c <= prev, "not cost-descending");
            prev = c;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cost_ties_keep_schedule_order() {
        // Block partition of 40 into 4 equal subsets: all 6 pair costs equal,
        // so the LPT order must fall back to schedule (id) order.
        let ds = uniform(40, 2, 1.0, Pcg64::seeded(3));
        let plan = ExecPlan::new(&ds, 4, PartitionStrategy::Block, 0);
        assert_eq!(plan.lpt_order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn parts_are_sorted_and_partition_everything() {
        let ds = uniform(33, 2, 1.0, Pcg64::seeded(4));
        let plan = ExecPlan::new(&ds, 4, PartitionStrategy::RandomShuffle, 7);
        let mut all: Vec<u32> = plan.parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..33).collect::<Vec<u32>>());
        for p in &plan.parts {
            assert!(p.windows(2).all(|w| w[0] < w[1]), "subset sorted");
        }
    }
}
