//! The execution plan: partition + pair schedule + per-job cost estimates.
//!
//! One plan serves every execution mode (serial, pooled, distributed): it
//! fixes *what* gets computed — the partition subsets and the pair jobs —
//! while the engine decides *where* and *in what order*. The degenerate
//! `|P| = 1` case is folded in as a single self-pair job (`i == j`), so the
//! engines have no special cases.

use crate::data::Dataset;
use crate::decomp::{partition_indices, PairJob, PairSchedule, PartitionStrategy};

/// Partition, schedule, and cost model for one decomposed-MST execution.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    /// the partition subsets, each sorted ascending by global id
    pub parts: Vec<Vec<u32>>,
    /// pair jobs in the paper's schedule order; for `|P| = 1` a single
    /// degenerate self-pair job `{id: 0, i: 0, j: 0}`
    pub jobs: Vec<PairJob>,
    /// job indices sorted by descending cost estimate (LPT deal order);
    /// ties keep schedule order
    pub lpt_order: Vec<usize>,
}

impl ExecPlan {
    /// Partition `ds` and lay out the pair jobs with their cost estimates.
    pub fn new(ds: &Dataset, parts: usize, strategy: PartitionStrategy, seed: u64) -> Self {
        Self::from_layout(partition_indices(ds, parts, strategy, seed))
    }

    /// Lay out the pair jobs over an already-fixed partition — the sharded
    /// leader's entry point, where the layout comes from a shard manifest
    /// and the vectors never reach the leader at all.
    pub fn from_layout(part_ids: Vec<Vec<u32>>) -> Self {
        let parts = part_ids.len();
        assert!(parts >= 1, "a plan needs at least one subset");
        let jobs: Vec<PairJob> = if parts == 1 {
            vec![PairJob { id: 0, i: 0, j: 0 }]
        } else {
            PairSchedule::new(parts).jobs
        };
        let costs: Vec<u64> = jobs.iter().map(|j| job_cost(&part_ids, j)).collect();
        let mut lpt_order: Vec<usize> = (0..jobs.len()).collect();
        lpt_order.sort_by(|&a, &b| costs[b].cmp(&costs[a]).then(a.cmp(&b)));
        Self { parts: part_ids, jobs, lpt_order }
    }

    /// Number of pair jobs (≥ 1; the degenerate single-subset job counts).
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// The cost estimate used to deal `job`: `|S_i|·|S_j|` — the size of the
    /// bipartite distance block, which dominates both pair kernels' work.
    pub fn job_cost(&self, job: &PairJob) -> u64 {
        job_cost(&self.parts, job)
    }

    /// Sizes of the partition subsets.
    pub fn part_sizes(&self) -> Vec<usize> {
        self.parts.iter().map(|p| p.len()).collect()
    }

    /// Build the subset-affinity schedule for `n_workers` workers.
    ///
    /// Anchor assignment is LPT over each subset's *total* pair-job cost
    /// (the sum of `|S_i|·|S_j|` over every job touching it): subsets are
    /// taken heaviest-first and each lands on the least-loaded worker, so
    /// the per-worker anchored work is balanced up to one subset. Every pair
    /// job is then routed to the anchor of its **larger** subset (ties: the
    /// lower subset index, i.e. `i`), which maximizes the bytes a resident
    /// subset saves, and each deck keeps the cost-descending LPT order so
    /// in-deck self-scheduling still takes heaviest-first.
    pub fn affinity(&self, n_workers: usize) -> AffinityPlan {
        assert!(n_workers >= 1, "affinity schedule needs at least one worker");
        let p = self.parts.len();
        let mut subset_cost = vec![0u64; p];
        for job in &self.jobs {
            let c = job_cost(&self.parts, job);
            subset_cost[job.i as usize] += c;
            if job.j != job.i {
                subset_cost[job.j as usize] += c;
            }
        }
        let mut order: Vec<usize> = (0..p).collect();
        order.sort_by(|&a, &b| subset_cost[b].cmp(&subset_cost[a]).then(a.cmp(&b)));
        let mut load = vec![0u64; n_workers];
        let mut anchor = vec![0usize; p];
        for k in order {
            let w = (0..n_workers).min_by_key(|&w| (load[w], w)).expect("n_workers >= 1");
            anchor[k] = w;
            load[w] += subset_cost[k];
        }
        let mut decks = vec![Vec::new(); n_workers];
        for &idx in &self.lpt_order {
            let job = &self.jobs[idx];
            let (i, j) = (job.i as usize, job.j as usize);
            let home = if self.parts[j].len() > self.parts[i].len() { anchor[j] } else { anchor[i] };
            decks[home].push(idx);
        }
        let mut local_decks = vec![Vec::new(); n_workers];
        let mut by_size: Vec<usize> = (0..p).collect();
        by_size.sort_by(|&a, &b| self.parts[b].len().cmp(&self.parts[a].len()).then(a.cmp(&b)));
        for k in by_size {
            local_decks[anchor[k]].push(k);
        }
        AffinityPlan { anchor, decks, local_decks }
    }

    /// Build the residency-constrained schedule of a sharded run, where
    /// worker `w` may only execute jobs whose *both* subsets it holds from
    /// local shard files (`holders[w][k]`).
    ///
    /// - each subset is anchored to the least-loaded **holder** (heaviest
    ///   subsets placed first, load = total routed pair-job cost);
    /// - job `(i, j)` lands on its larger subset's anchor when that worker
    ///   holds both, otherwise on the least-loaded capable worker;
    /// - errors if some subset has no holder or some pair of subsets is
    ///   co-resident nowhere — the shard assignment cannot cover the run
    ///   (see `shard::suggest_assignment` for a covering layout).
    ///
    /// Also returns the per-worker capability mask over the job list, which
    /// [`super::JobQueue::with_decks_capped`] uses to confine claims (and
    /// failure-returned jobs) to capable workers.
    pub fn affinity_for_holders(
        &self,
        holders: &[Vec<bool>],
    ) -> anyhow::Result<(AffinityPlan, Vec<Vec<bool>>)> {
        let n_workers = holders.len();
        anyhow::ensure!(n_workers >= 1, "sharded schedule needs at least one worker");
        let p = self.parts.len();
        for (w, h) in holders.iter().enumerate() {
            anyhow::ensure!(h.len() == p, "holder mask of worker {w} has wrong length");
        }
        for k in 0..p {
            anyhow::ensure!(
                holders.iter().any(|h| h[k]),
                "subset {k} is resident on no worker — start a worker with --shard-ids including {k}"
            );
        }
        let caps: Vec<Vec<bool>> = holders
            .iter()
            .map(|h| {
                self.jobs
                    .iter()
                    .map(|job| h[job.i as usize] && h[job.j as usize])
                    .collect()
            })
            .collect();
        for (idx, job) in self.jobs.iter().enumerate() {
            anyhow::ensure!(
                caps.iter().any(|c| c[idx]),
                "pair job ({}, {}) has no worker holding both subsets — the shard assignment must co-locate every subset pair (try the layout `demst partition` suggests)",
                job.i,
                job.j
            );
        }

        let mut subset_cost = vec![0u64; p];
        for job in &self.jobs {
            let c = job_cost(&self.parts, job);
            subset_cost[job.i as usize] += c;
            if job.j != job.i {
                subset_cost[job.j as usize] += c;
            }
        }
        let mut order: Vec<usize> = (0..p).collect();
        order.sort_by(|&a, &b| subset_cost[b].cmp(&subset_cost[a]).then(a.cmp(&b)));
        let mut load = vec![0u64; n_workers];
        let mut anchor = vec![0usize; p];
        for k in order {
            let w = (0..n_workers)
                .filter(|&w| holders[w][k])
                .min_by_key(|&w| (load[w], w))
                .expect("checked: every subset has a holder");
            anchor[k] = w;
            load[w] += subset_cost[k];
        }
        // Route jobs: larger subset's anchor when capable, else the least
        // job-loaded capable worker (tracked separately from the anchor
        // load so the fallback spreads instead of piling on one host).
        let mut deck_load = vec![0u64; n_workers];
        let mut decks = vec![Vec::new(); n_workers];
        for &idx in &self.lpt_order {
            let job = &self.jobs[idx];
            let (i, j) = (job.i as usize, job.j as usize);
            let big = if self.parts[j].len() > self.parts[i].len() { j } else { i };
            let preferred = anchor[big];
            let home = if caps[preferred][idx] {
                preferred
            } else {
                (0..n_workers)
                    .filter(|&w| caps[w][idx])
                    .min_by_key(|&w| (deck_load[w], w))
                    .expect("checked: every job has a capable worker")
            };
            deck_load[home] += job_cost(&self.parts, job);
            decks[home].push(idx);
        }
        let mut local_decks = vec![Vec::new(); n_workers];
        let mut by_size: Vec<usize> = (0..p).collect();
        by_size.sort_by(|&a, &b| self.parts[b].len().cmp(&self.parts[a].len()).then(a.cmp(&b)));
        for k in by_size {
            local_decks[anchor[k]].push(k);
        }
        Ok((AffinityPlan { anchor, decks, local_decks }, caps))
    }
}

/// The subset-affinity schedule: an anchor worker per subset plus per-worker
/// job decks. Produced by [`ExecPlan::affinity`]; consumed by the deck-based
/// [`super::JobQueue`] and the engine's resident-set scatter model.
#[derive(Clone, Debug)]
pub struct AffinityPlan {
    /// subset index → anchor worker
    pub anchor: Vec<usize>,
    /// worker → pair-job indices, cost-descending within each deck; a job
    /// sits in the deck of its larger subset's anchor
    pub decks: Vec<Vec<usize>>,
    /// worker → subset indices for the local-MST phase (bipartite kernel),
    /// size-descending; each subset is built at its anchor so its vectors
    /// are already resident when the pair phase starts
    pub local_decks: Vec<Vec<usize>>,
}

fn job_cost(parts: &[Vec<u32>], job: &PairJob) -> u64 {
    let si = parts[job.i as usize].len() as u64;
    if job.i == job.j {
        si * si
    } else {
        si * parts[job.j as usize].len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::uniform;
    use crate::util::prng::Pcg64;

    #[test]
    fn degenerate_single_part_has_one_self_job() {
        let ds = uniform(20, 3, 1.0, Pcg64::seeded(1));
        let plan = ExecPlan::new(&ds, 1, PartitionStrategy::Block, 0);
        assert_eq!(plan.n_jobs(), 1);
        assert_eq!((plan.jobs[0].i, plan.jobs[0].j), (0, 0));
        assert_eq!(plan.job_cost(&plan.jobs[0]), 400);
    }

    #[test]
    fn lpt_order_is_cost_descending_and_complete() {
        let ds = uniform(50, 2, 1.0, Pcg64::seeded(2));
        let plan = ExecPlan::new(&ds, 5, PartitionStrategy::Block, 0);
        assert_eq!(plan.n_jobs(), 10);
        assert_eq!(plan.lpt_order.len(), 10);
        let mut seen = vec![false; 10];
        let mut prev = u64::MAX;
        for &k in &plan.lpt_order {
            assert!(!seen[k], "job {k} dealt twice");
            seen[k] = true;
            let c = plan.job_cost(&plan.jobs[k]);
            assert!(c <= prev, "not cost-descending");
            prev = c;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn cost_ties_keep_schedule_order() {
        // Block partition of 40 into 4 equal subsets: all 6 pair costs equal,
        // so the LPT order must fall back to schedule (id) order.
        let ds = uniform(40, 2, 1.0, Pcg64::seeded(3));
        let plan = ExecPlan::new(&ds, 4, PartitionStrategy::Block, 0);
        assert_eq!(plan.lpt_order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn affinity_decks_partition_jobs_and_route_to_larger_subsets_anchor() {
        let ds = uniform(60, 3, 1.0, Pcg64::seeded(5));
        let plan = ExecPlan::new(&ds, 5, PartitionStrategy::RandomShuffle, 11);
        for n_workers in [1usize, 2, 3, 7] {
            let aff = plan.affinity(n_workers);
            assert_eq!(aff.decks.len(), n_workers);
            assert_eq!(aff.local_decks.len(), n_workers);
            assert_eq!(aff.anchor.len(), 5);
            assert!(aff.anchor.iter().all(|&w| w < n_workers));
            // every pair job dealt exactly once, in its larger subset's deck
            let mut seen = vec![false; plan.n_jobs()];
            for (w, deck) in aff.decks.iter().enumerate() {
                let mut prev = u64::MAX;
                for &idx in deck {
                    assert!(!seen[idx], "job {idx} dealt twice");
                    seen[idx] = true;
                    let job = &plan.jobs[idx];
                    let (i, j) = (job.i as usize, job.j as usize);
                    let big =
                        if plan.parts[j].len() > plan.parts[i].len() { j } else { i };
                    assert_eq!(aff.anchor[big], w, "job {idx} off its anchor deck");
                    let c = plan.job_cost(job);
                    assert!(c <= prev, "deck {w} not cost-descending");
                    prev = c;
                }
            }
            assert!(seen.iter().all(|&s| s), "workers={n_workers}");
            // every subset built exactly once, at its anchor
            let mut built = vec![false; 5];
            for (w, deck) in aff.local_decks.iter().enumerate() {
                for &k in deck {
                    assert!(!built[k]);
                    built[k] = true;
                    assert_eq!(aff.anchor[k], w);
                }
            }
            assert!(built.iter().all(|&b| b));
        }
    }

    #[test]
    fn affinity_anchors_spread_over_workers() {
        // Equal-size subsets, workers >= subsets: LPT puts one subset per
        // worker (lowest indices first).
        let ds = uniform(40, 2, 1.0, Pcg64::seeded(6));
        let plan = ExecPlan::new(&ds, 4, PartitionStrategy::Block, 0);
        let aff = plan.affinity(6);
        let mut anchors = aff.anchor.clone();
        anchors.sort_unstable();
        assert_eq!(anchors, vec![0, 1, 2, 3]);
    }

    #[test]
    fn from_layout_matches_new() {
        let ds = uniform(48, 3, 1.0, Pcg64::seeded(8));
        let a = ExecPlan::new(&ds, 4, PartitionStrategy::RandomShuffle, 3);
        let b = ExecPlan::from_layout(a.parts.clone());
        assert_eq!(a.parts, b.parts);
        assert_eq!(a.jobs, b.jobs);
        assert_eq!(a.lpt_order, b.lpt_order);
    }

    #[test]
    fn holders_schedule_routes_only_to_capable_workers() {
        let ds = uniform(60, 3, 1.0, Pcg64::seeded(9));
        let plan = ExecPlan::new(&ds, 4, PartitionStrategy::Block, 0);
        // worker 0 holds everything, worker 1 holds {2, 3}
        let holders = vec![vec![true; 4], vec![false, false, true, true]];
        let (aff, caps) = plan.affinity_for_holders(&holders).unwrap();
        assert_eq!(aff.decks.len(), 2);
        let mut seen = vec![false; plan.n_jobs()];
        for (w, deck) in aff.decks.iter().enumerate() {
            for &idx in deck {
                assert!(!seen[idx]);
                seen[idx] = true;
                let job = &plan.jobs[idx];
                assert!(
                    holders[w][job.i as usize] && holders[w][job.j as usize],
                    "job {idx} routed to worker {w} missing a subset"
                );
                assert!(caps[w][idx]);
            }
        }
        assert!(seen.iter().all(|&s| s));
        // every subset built at a holder
        for (k, &a) in aff.anchor.iter().enumerate() {
            assert!(holders[a][k], "subset {k} anchored off-holder");
        }
        // worker 1 cannot run jobs touching subsets 0/1
        for (idx, job) in plan.jobs.iter().enumerate() {
            if job.i < 2 || job.j < 2 {
                assert!(!caps[1][idx]);
            }
        }
    }

    #[test]
    fn holders_schedule_rejects_uncovered_layouts() {
        let ds = uniform(40, 2, 1.0, Pcg64::seeded(10));
        let plan = ExecPlan::new(&ds, 4, PartitionStrategy::Block, 0);
        // subset 3 resident nowhere
        let holders = vec![vec![true, true, true, false]];
        let err = plan.affinity_for_holders(&holders).unwrap_err().to_string();
        assert!(err.contains("subset 3"), "{err}");
        // all subsets covered, but pair (0, 3) co-resident nowhere
        let holders = vec![vec![true, true, true, false], vec![false, false, true, true]];
        let err = plan.affinity_for_holders(&holders).unwrap_err().to_string();
        assert!(err.contains("no worker holding both"), "{err}");
    }

    #[test]
    fn parts_are_sorted_and_partition_everything() {
        let ds = uniform(33, 2, 1.0, Pcg64::seeded(4));
        let plan = ExecPlan::new(&ds, 4, PartitionStrategy::RandomShuffle, 7);
        let mut all: Vec<u32> = plan.parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..33).collect::<Vec<u32>>());
        for p in &plan.parts {
            assert!(p.windows(2).all(|w| w[0] < w[1]), "subset sorted");
        }
    }
}
