//! The pair-job execution engine — the single implementation of
//! partition → schedule → solve → reduce that *both* front-ends are thin
//! wrappers over:
//!
//! - [`crate::decomp::decomposed_mst`] — serial reference: [`run_serial`]
//!   over a [`DensePairSolver`] borrowing the caller's kernel;
//! - [`crate::coordinator::run_distributed`] — distributed:
//!   [`execute_pooled`] with `std::thread` workers, cost-LPT dealing with
//!   idle stealing ([`JobQueue`]), byte accounting against a
//!   [`Transport`](crate::net::Transport) — the simulated
//!   [`NetSim`](crate::net::NetSim), or (via [`execute_pooled_remote`])
//!   real TCP links with each pool thread driving a remote `demst worker`
//!   process through a windowed, elastic
//!   [`RemoteLink`](crate::net::remote::RemoteLink) (up to
//!   `pipeline_window` jobs in flight per link; a dead link's jobs return
//!   to the deck and the surviving fleet finishes the run) — and optional
//!   streaming ⊕-reduction at the leader. [`execute_pooled_sharded`] runs
//!   the same engine with **no leader-resident vectors at all**: the plan
//!   comes from a shard manifest and scheduling is confined to workers
//!   whose local shard files hold both subsets of each job.
//!
//! The layer's pieces:
//! - [`plan`] — [`ExecPlan`]: partition subsets + pair jobs + the
//!   `|S_i|·|S_j|` cost model (degenerate `|P| = 1` folded in), plus the
//!   [`AffinityPlan`]: an anchor worker per subset (LPT over each subset's
//!   total pair-job cost) with jobs routed to their larger subset's anchor;
//! - [`scheduler`] — [`JobQueue`]: per-worker affinity decks (or one shared
//!   LPT deck), atomic claims, idle workers steal from other decks;
//! - [`pair_kernel`] — the two pair kernels behind [`PairSolver`]: the
//!   dense oracle and the cycle-property [`BipartitePairSolver`] with
//!   cached local MSTs ([`LocalMstCache`]) + filtered Prim over one
//!   bipartite block, computed as an `S_i × S_j` panel product from a
//!   per-worker [`PanelCache`];
//! - [`engine`] — the serial and pooled drivers, the resident-set scatter
//!   model (charge only what the executing worker doesn't hold; the dense
//!   model stays byte-for-byte behind `affinity = false`), and per-phase
//!   metrics.

pub mod engine;
pub mod pair_kernel;
pub mod plan;
pub mod scheduler;

pub use engine::{
    decomposed_mst_bipartite, execute_pooled, execute_pooled_remote, execute_pooled_sharded,
    resolve_workers, run_serial, PooledRun, SerialRun,
};
pub use pair_kernel::{
    bipartite_filtered_prim, bipartite_filtered_prim_blocked, emit_tree, subset_mst,
    subset_mst_gathered, BipartiteCtx, BipartitePairSolver, DensePairSolver, KeyedLru,
    LocalMstCache, PairSolver, PanelCache, PanelPerf, Shipment, Solved, SolverFinal,
    SubsetPanel, PANEL_CACHE_CAP,
};
pub use plan::{AffinityPlan, ExecPlan};
pub use scheduler::JobQueue;
