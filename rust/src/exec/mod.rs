//! The pair-job execution engine — the single implementation of
//! partition → schedule → solve → reduce that *both* front-ends are thin
//! wrappers over:
//!
//! - [`crate::decomp::decomposed_mst`] — serial reference: [`run_serial`]
//!   over a [`DensePairSolver`] borrowing the caller's kernel;
//! - [`crate::coordinator::run_distributed`] — distributed:
//!   [`execute_pooled`] with `std::thread` workers, cost-LPT dealing with
//!   idle stealing ([`JobQueue`]), [`NetSim`](crate::coordinator::NetSim)
//!   byte accounting, and optional streaming ⊕-reduction at the leader.
//!
//! The layer's pieces:
//! - [`plan`] — [`ExecPlan`]: partition subsets + pair jobs + the
//!   `|S_i|·|S_j|` cost model (degenerate `|P| = 1` folded in);
//! - [`scheduler`] — [`JobQueue`]: atomic LPT deal, workers steal when idle;
//! - [`pair_kernel`] — the two pair kernels behind [`PairSolver`]: the
//!   dense oracle and the cycle-property [`BipartitePairSolver`] with
//!   cached local MSTs ([`LocalMstCache`]) + filtered Prim over one
//!   bipartite block;
//! - [`engine`] — the serial and pooled drivers plus per-phase metrics.

pub mod engine;
pub mod pair_kernel;
pub mod plan;
pub mod scheduler;

pub use engine::{
    decomposed_mst_bipartite, execute_pooled, resolve_workers, run_serial, PooledRun, SerialRun,
};
pub use pair_kernel::{
    bipartite_filtered_prim, emit_tree, subset_mst, BipartiteCtx, BipartitePairSolver,
    DensePairSolver, LocalMstCache, PairSolver,
};
pub use plan::ExecPlan;
pub use scheduler::JobQueue;
