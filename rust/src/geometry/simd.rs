//! Register-tiled SIMD panel kernels with runtime ISA dispatch and a
//! **canonical reduction order** shared by every path.
//!
//! This module is the FLOP engine behind
//! [`DistanceBlock::panel_block`](super::blocked::DistanceBlock::panel_block):
//! the `S_i × S_j` bipartite blocks of the pair kernel are matmul-shaped
//! panel products, and this layer computes them with AVX2 (x86_64) or NEON
//! (aarch64) micro-kernels, an intra-job threaded row-band path, and a
//! scalar fallback — all **bit-identical** to each other and to the
//! [`row`](super::blocked::DistanceBlock::row) path.
//!
//! ## The canonical reduction order
//!
//! IEEE-754 addition is not associative, so a vectorized reduction that
//! sums in a different order than the scalar code produces different bits,
//! which would perturb the strict `(w, u, v)` edge order the whole engine
//! is built on. Instead of tolerating drift, *every* path commits to one
//! fixed accumulation order, defined by the widest kernel:
//!
//! 1. **Lane split.** A length-`d` reduction runs [`LANES`] (= 8)
//!    independent accumulators; lane `l` sums terms `8c + l` in chunk
//!    order `c = 0, 1, …`.
//! 2. **Virtual zero padding.** When `d % 8 != 0` the final chunk is
//!    processed as a full chunk over a zero-padded tail: lanes `< d % 8`
//!    add their real term, lanes `≥ d % 8` add an explicit `+0.0` — the
//!    exact contribution of the zero-padded pad region of a packed panel
//!    (`0·0 = +0.0` for the dot form, `|0−0| = +0.0` for L1).
//! 3. **Fixed reduction tree.** The 8 lane sums collapse as
//!    `((s0+s1)+(s2+s3)) + ((s4+s5)+(s6+s7))`.
//!
//! The scalar kernels ([`dot_canonical`], [`l1_canonical`]) implement this
//! order directly, so "scalar" here *is* the canonical order — there is no
//! separate legacy order to diverge from.
//!
//! ## The no-fused-ops rule
//!
//! A fused multiply-add rounds once where `mul` + `add` round twice, so a
//! single FMA anywhere breaks bit-identity with the scalar path. The rule:
//! **no fused operations on any path.**
//!
//! - AVX2 kernels use `_mm256_mul_ps` + `_mm256_add_ps`, never
//!   `_mm256_fmadd_ps` (FMA is *detected* — it rides along with every
//!   AVX2 part we care about — but never *issued*).
//! - NEON kernels use `vmulq_f32` + `vaddq_f32`, never `vmlaq_f32`/
//!   `vfmaq_f32` (which lower to fused `fmla`).
//! - Scalar Rust is safe by language guarantee: rustc never contracts
//!   `a * b + c` into an FMA, at any `-C target-cpu`/opt-level. The CI
//!   `-C target-cpu=native` leg exists to catch this rule regressing.
//!
//! ## Threading
//!
//! [`PanelSettings::threads`] > 1 splits the output into contiguous row
//! bands computed by `std::thread::scope` workers. Every output element is
//! owned by exactly one band and each element's reduction order is fixed
//! by the rules above, so the result is bit-identical for *any* thread
//! count — [`planned_threads`] is a pure function of the panel shape used
//! only to decide how much parallelism is worth spawning.

use super::metric::MetricKind;

/// Canonical accumulation width: f32 lanes of one AVX2 vector. NEON
/// emulates the 8-lane split with two 4-lane registers; scalar code runs
/// 8 independent accumulators.
pub const LANES: usize = 8;

/// Panel row stride that fits whole SIMD chunks: `d` rounded up to a
/// multiple of [`LANES`]. The pad region `d..stride` of a packed panel
/// row must be zero (see [`pad_rows`]).
pub fn padded_stride(d: usize) -> usize {
    d.div_ceil(LANES) * LANES
}

/// Pack `n` contiguous rows of `d` values into a zero-padded
/// `(n, padded_stride(d))` panel. Returns the panel and its stride.
pub fn pad_rows(data: &[f32], n: usize, d: usize) -> (Vec<f32>, usize) {
    debug_assert_eq!(data.len(), n * d);
    let stride = padded_stride(d);
    let mut p = vec![0.0f32; n * stride];
    for i in 0..n {
        p[i * stride..i * stride + d].copy_from_slice(&data[i * d..(i + 1) * d]);
    }
    (p, stride)
}

/// Instruction set the panel kernels dispatch to at runtime.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// Canonical scalar kernels (the reduction-order reference).
    Scalar,
    /// 8-wide AVX2 register-tiled kernels (x86_64; FMA detected but never
    /// issued — see the no-fused-ops rule).
    Avx2,
    /// 4-wide NEON kernels emulating the 8-lane canonical split (aarch64).
    Neon,
}

impl Isa {
    pub fn label(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Hardware f32 vector width of this ISA's registers (the *canonical*
    /// accumulation width is always [`LANES`]).
    pub fn lanes(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 8,
            Isa::Neon => 4,
        }
    }

    /// Stable small code for the wire (`WorkerDone.panel_isa`); 0 is
    /// reserved for "no panel work ran".
    pub fn wire_code(self) -> u8 {
        match self {
            Isa::Scalar => 1,
            Isa::Avx2 => 2,
            Isa::Neon => 3,
        }
    }

    pub fn from_wire_code(code: u8) -> Option<Isa> {
        match code {
            1 => Some(Isa::Scalar),
            2 => Some(Isa::Avx2),
            3 => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// True when `DEMST_SIMD` requests the forced-scalar path (`off`, `0`, or
/// `scalar`) — the env-var escape hatch the tests and the CI scalar leg use.
pub fn simd_disabled_by_env() -> bool {
    matches!(
        std::env::var("DEMST_SIMD").as_deref(),
        Ok("off") | Ok("0") | Ok("scalar")
    )
}

/// What the hardware supports, ignoring the env override.
fn detect_isa_hw() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        // Require FMA alongside AVX2 (they ship together on every AVX2
        // part this targets) even though fused ops are never issued: the
        // pair is what "AVX2-class" means operationally.
        if std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma") {
            Isa::Avx2
        } else {
            Isa::Scalar
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // Advanced SIMD is architecturally mandatory on AArch64.
        Isa::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Isa::Scalar
    }
}

/// Runtime ISA choice: hardware detection, unless `DEMST_SIMD=off` forces
/// the canonical scalar path.
pub fn detect_isa() -> Isa {
    if simd_disabled_by_env() {
        Isa::Scalar
    } else {
        detect_isa_hw()
    }
}

/// Why the panel path is not running SIMD, if it is not (`None` = SIMD
/// active). Pure function of config + environment + hardware, mirroring
/// [`crate::runtime::kernel_fallback_note`].
pub fn panel_fallback_note(panel_simd_enabled: bool) -> Option<String> {
    if !panel_simd_enabled {
        return Some("panel_simd=off in config forces the canonical scalar panel path".into());
    }
    if simd_disabled_by_env() {
        return Some("DEMST_SIMD=off forces the canonical scalar panel path".into());
    }
    match detect_isa_hw() {
        Isa::Scalar => {
            #[cfg(target_arch = "x86_64")]
            {
                Some("AVX2+FMA not detected on this x86_64 host; canonical scalar panel path".into())
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                Some("no SIMD panel kernel for this architecture; canonical scalar panel path".into())
            }
        }
        _ => None,
    }
}

/// Per-run panel execution settings: the dispatched ISA and the intra-job
/// thread budget. Carried by every [`super::blocked::DistanceBlock`]; pure
/// speed knobs — **no setting changes a single output bit**.
#[derive(Clone, Copy, Debug)]
pub struct PanelSettings {
    pub isa: Isa,
    /// Max worker threads for one panel (≥ 1). The actual count per call
    /// is [`planned_threads`], a pure function of the panel shape.
    pub threads: usize,
}

impl PanelSettings {
    /// Forced-scalar, single-threaded — the reduction-order reference.
    pub fn scalar() -> Self {
        Self { isa: Isa::Scalar, threads: 1 }
    }

    /// Environment-driven detection: hardware ISA (unless `DEMST_SIMD=off`)
    /// and all available cores (unless `DEMST_PANEL_THREADS` caps them).
    pub fn detect() -> Self {
        let threads = std::env::var("DEMST_PANEL_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| (1..=256).contains(&t))
            .unwrap_or_else(default_threads);
        Self { isa: detect_isa(), threads }
    }

    /// Resolve from config knobs: `panel_simd` gates dispatch (env
    /// `DEMST_SIMD=off` still wins), `panel_threads = 0` means all
    /// available cores.
    pub fn from_config(panel_simd: bool, panel_threads: usize) -> Self {
        let isa = if panel_simd { detect_isa() } else { Isa::Scalar };
        let threads = if panel_threads == 0 { default_threads() } else { panel_threads.clamp(1, 256) };
        Self { isa, threads }
    }
}

/// Available cores at this process, clamped to the config bound (256).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(256)
}

/// FLOP model for one `(m, n, d)` panel: 2 FLOPs per dimension per element
/// for the Gram/dot metrics (mul + add), 3 for Manhattan (sub, abs, add).
pub fn panel_flops(kind: MetricKind, m: usize, n: usize, d: usize) -> u64 {
    let per_dim: u64 = match kind {
        MetricKind::SqEuclid | MetricKind::Euclid | MetricKind::Cosine => 2,
        MetricKind::Manhattan => 3,
    };
    per_dim * (m as u64) * (n as u64) * (d as u64)
}

/// The canonical fixed reduction tree over the 8 lane sums.
#[inline]
pub fn reduce_lanes(s: &[f32; LANES]) -> f32 {
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
}

/// Canonical dot product: 8-lane split accumulation in chunk order, virtual
/// zero padding for the tail, fixed reduction tree. This *defines* the
/// reduction order every SIMD path must reproduce bit-for-bit.
#[inline]
pub fn dot_canonical(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let d = a.len();
    let chunks = d / LANES;
    let rem = d - chunks * LANES;
    let mut acc = [0.0f32; LANES];
    for (xa, xb) in a.chunks_exact(LANES).zip(b.chunks_exact(LANES)) {
        let xa: &[f32; LANES] = xa.try_into().unwrap();
        let xb: &[f32; LANES] = xb.try_into().unwrap();
        for (l, s) in acc.iter_mut().enumerate() {
            *s += xa[l] * xb[l];
        }
    }
    if rem > 0 {
        let j = chunks * LANES;
        for (l, s) in acc.iter_mut().enumerate() {
            // virtual zero padding: lanes past the remainder add the +0.0 a
            // zero-padded panel's pad region contributes
            *s += if l < rem { a[j + l] * b[j + l] } else { 0.0 };
        }
    }
    reduce_lanes(&acc)
}

/// Canonical L1 accumulation: same lane split, padding, and reduction tree
/// as [`dot_canonical`] with `|a−b|` terms (`|0−0| = +0.0` in the pad).
#[inline]
pub fn l1_canonical(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let d = a.len();
    let chunks = d / LANES;
    let rem = d - chunks * LANES;
    let mut acc = [0.0f32; LANES];
    for (xa, xb) in a.chunks_exact(LANES).zip(b.chunks_exact(LANES)) {
        let xa: &[f32; LANES] = xa.try_into().unwrap();
        let xb: &[f32; LANES] = xb.try_into().unwrap();
        for (l, s) in acc.iter_mut().enumerate() {
            *s += (xa[l] - xb[l]).abs();
        }
    }
    if rem > 0 {
        let j = chunks * LANES;
        for (l, s) in acc.iter_mut().enumerate() {
            *s += if l < rem { (a[j + l] - b[j + l]).abs() } else { 0.0 };
        }
    }
    reduce_lanes(&acc)
}

/// The two accumulation forms the micro-kernels implement.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum PanelOp {
    /// `Σ a·b` — Gram/dot form (sqeuclid, cosine).
    Dot,
    /// `Σ |a−b|` — Manhattan.
    AbsDiff,
}

/// Threads actually used for one `(m, n, d)` panel under `settings`: pure
/// in the shape, so runs are reproducible and the witness is meaningful.
/// Small panels stay single-threaded (spawn cost would dominate).
pub fn planned_threads(settings: PanelSettings, m: usize, n: usize, d: usize) -> usize {
    /// Minimum per-thread share of the accumulation work (mul-add terms)
    /// before another band is worth spawning.
    const MIN_WORK_PER_THREAD: usize = 1 << 16;
    if settings.threads <= 1 || m < 2 {
        return 1;
    }
    let work = m * n * d.max(1);
    settings.threads.min(m).min((work / MIN_WORK_PER_THREAD).max(1))
}

/// SIMD requires whole-chunk loads: the row stride must cover the padded
/// width and stay lane-aligned, else the call degrades (bit-identically)
/// to the canonical scalar kernels.
fn effective_isa(isa: Isa, d: usize, stride: usize) -> Isa {
    if stride >= padded_stride(d) && stride % LANES == 0 {
        isa
    } else {
        Isa::Scalar
    }
}

/// Scalar accumulation band: canonical kernels over the real `d` prefix of
/// each row (stride-agnostic).
fn accum_scalar(
    op: PanelOp,
    a: &[f32],
    r0: usize,
    rows: usize,
    b: &[f32],
    n: usize,
    d: usize,
    stride: usize,
    out: &mut [f32],
) {
    for ii in 0..rows {
        let ar = &a[(r0 + ii) * stride..(r0 + ii) * stride + d];
        for (j, o) in out[ii * n..(ii + 1) * n].iter_mut().enumerate() {
            let br = &b[j * stride..j * stride + d];
            *o = match op {
                PanelOp::Dot => dot_canonical(ar, br),
                PanelOp::AbsDiff => l1_canonical(ar, br),
            };
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{reduce_lanes, PanelOp, LANES};
    use std::arch::x86_64::*;

    /// Register tile: MR×NR output accumulators live in ymm registers
    /// (8 accumulators + 4 row vectors + 2 column vectors = 14 of 16).
    const MR: usize = 4;
    const NR: usize = 2;

    /// AVX2 accumulation band. Per the no-fused-ops rule this issues only
    /// `mul` then `add` (`vmulps` + `vaddps`) — never `vfmadd*` — so each
    /// lane's partial sum rounds exactly like the canonical scalar lane.
    ///
    /// # Safety
    /// AVX2 must be available; `a`/`b` must hold `(rows_total, stride)` /
    /// `(n, stride)` panels with `stride` a multiple of [`LANES`] covering
    /// the zero-padded width (checked by the dispatcher).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn accum_band(
        op: PanelOp,
        a: &[f32],
        r0: usize,
        rows: usize,
        b: &[f32],
        n: usize,
        d: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        let chunks = d.div_ceil(LANES);
        debug_assert!(chunks * LANES <= stride);
        let sign = _mm256_set1_ps(-0.0);
        let mut i = 0;
        while i < rows {
            let mi = MR.min(rows - i);
            let mut j = 0;
            while j < n {
                let nj = NR.min(n - j);
                let mut acc = [[_mm256_setzero_ps(); NR]; MR];
                for c in 0..chunks {
                    let off = c * LANES;
                    let mut vb = [_mm256_setzero_ps(); NR];
                    for (jj, v) in vb.iter_mut().enumerate().take(nj) {
                        *v = _mm256_loadu_ps(b.as_ptr().add((j + jj) * stride + off));
                    }
                    for (ii, arow) in acc.iter_mut().enumerate().take(mi) {
                        let va = _mm256_loadu_ps(a.as_ptr().add((r0 + i + ii) * stride + off));
                        for (jj, s) in arow.iter_mut().enumerate().take(nj) {
                            let p = match op {
                                PanelOp::Dot => _mm256_mul_ps(va, vb[jj]),
                                // |a−b| as sign-bit clear: bitwise-identical
                                // to scalar `.abs()`
                                PanelOp::AbsDiff => {
                                    _mm256_andnot_ps(sign, _mm256_sub_ps(va, vb[jj]))
                                }
                            };
                            *s = _mm256_add_ps(*s, p);
                        }
                    }
                }
                for (ii, arow) in acc.iter().enumerate().take(mi) {
                    for (jj, s) in arow.iter().enumerate().take(nj) {
                        let mut lanes = [0.0f32; LANES];
                        _mm256_storeu_ps(lanes.as_mut_ptr(), *s);
                        out[(i + ii) * n + (j + jj)] = reduce_lanes(&lanes);
                    }
                }
                j += nj;
            }
            i += mi;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{reduce_lanes, PanelOp, LANES};
    use std::arch::aarch64::*;

    const MR: usize = 4;
    const NR: usize = 2;

    /// NEON accumulation band: each output owns a lo/hi pair of 4-lane
    /// accumulators emulating the canonical 8-lane split. Per the
    /// no-fused-ops rule this issues `vmulq_f32` + `vaddq_f32` (`fmul` +
    /// `fadd`) — never `vmlaq_f32`/`vfmaq_f32`, which lower to fused
    /// `fmla` and would round differently.
    ///
    /// # Safety
    /// Same panel-layout contract as the AVX2 band (see dispatcher).
    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn accum_band(
        op: PanelOp,
        a: &[f32],
        r0: usize,
        rows: usize,
        b: &[f32],
        n: usize,
        d: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        let chunks = d.div_ceil(LANES);
        debug_assert!(chunks * LANES <= stride);
        let mut i = 0;
        while i < rows {
            let mi = MR.min(rows - i);
            let mut j = 0;
            while j < n {
                let nj = NR.min(n - j);
                let mut acc_lo = [[vdupq_n_f32(0.0); NR]; MR];
                let mut acc_hi = [[vdupq_n_f32(0.0); NR]; MR];
                for c in 0..chunks {
                    let off = c * LANES;
                    let mut vb_lo = [vdupq_n_f32(0.0); NR];
                    let mut vb_hi = [vdupq_n_f32(0.0); NR];
                    for jj in 0..nj {
                        let p = b.as_ptr().add((j + jj) * stride + off);
                        vb_lo[jj] = vld1q_f32(p);
                        vb_hi[jj] = vld1q_f32(p.add(4));
                    }
                    for ii in 0..mi {
                        let p = a.as_ptr().add((r0 + i + ii) * stride + off);
                        let va_lo = vld1q_f32(p);
                        let va_hi = vld1q_f32(p.add(4));
                        for jj in 0..nj {
                            let (plo, phi) = match op {
                                PanelOp::Dot => {
                                    (vmulq_f32(va_lo, vb_lo[jj]), vmulq_f32(va_hi, vb_hi[jj]))
                                }
                                PanelOp::AbsDiff => (
                                    vabsq_f32(vsubq_f32(va_lo, vb_lo[jj])),
                                    vabsq_f32(vsubq_f32(va_hi, vb_hi[jj])),
                                ),
                            };
                            acc_lo[ii][jj] = vaddq_f32(acc_lo[ii][jj], plo);
                            acc_hi[ii][jj] = vaddq_f32(acc_hi[ii][jj], phi);
                        }
                    }
                }
                for ii in 0..mi {
                    for jj in 0..nj {
                        let mut lanes = [0.0f32; LANES];
                        vst1q_f32(lanes.as_mut_ptr(), acc_lo[ii][jj]);
                        vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi[ii][jj]);
                        out[(i + ii) * n + (j + jj)] = reduce_lanes(&lanes);
                    }
                }
                j += nj;
            }
            i += mi;
        }
    }
}

/// One accumulation band, dispatched to the effective ISA.
#[allow(clippy::too_many_arguments)]
fn accum_band(
    isa: Isa,
    op: PanelOp,
    a: &[f32],
    r0: usize,
    rows: usize,
    b: &[f32],
    n: usize,
    d: usize,
    stride: usize,
    out: &mut [f32],
) {
    debug_assert!(a.len() >= (r0 + rows) * stride);
    debug_assert!(b.len() >= n * stride);
    debug_assert_eq!(out.len(), rows * n);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { avx2::accum_band(op, a, r0, rows, b, n, d, stride, out) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::accum_band(op, a, r0, rows, b, n, d, stride, out) },
        _ => accum_scalar(op, a, r0, rows, b, n, d, stride, out),
    }
}

/// Split `out` into contiguous row bands and run `f(first_row, rows, band)`
/// on [`planned_threads`] scoped threads (inline when the plan is 1 —
/// no spawn cost on small panels). Band boundaries never change results:
/// each output element is computed independently in the canonical order.
fn run_bands(
    settings: PanelSettings,
    m: usize,
    n: usize,
    d: usize,
    out: &mut [f32],
    f: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(out.len(), m * n);
    let t = planned_threads(settings, m, n, d);
    if t <= 1 {
        f(0, m, out);
        return;
    }
    let band_rows = m.div_ceil(t);
    std::thread::scope(|sc| {
        let mut rest = out;
        let mut r0 = 0;
        while r0 < m {
            let rows = band_rows.min(m - r0);
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(rows * n);
            rest = tail;
            let fr = &f;
            sc.spawn(move || fr(r0, rows, band));
            r0 += rows;
        }
    });
}

/// Squared-Euclidean `(m, n)` panel over packed `(·, stride)` panels with
/// precomputed squared norms: `na[i] + nb[j] − 2·dot`, clamped at zero —
/// element-for-element the `row` path's arithmetic, dot in the canonical
/// order.
#[allow(clippy::too_many_arguments)]
pub fn sqeuclid_panel(
    settings: PanelSettings,
    a: &[f32],
    na: &[f32],
    m: usize,
    b: &[f32],
    nb: &[f32],
    n: usize,
    d: usize,
    stride: usize,
    out: &mut [f32],
) {
    debug_assert!(stride >= d);
    debug_assert_eq!(a.len(), m * stride);
    debug_assert_eq!(b.len(), n * stride);
    debug_assert_eq!(na.len(), m);
    debug_assert_eq!(nb.len(), n);
    let isa = effective_isa(settings.isa, d, stride);
    run_bands(settings, m, n, d, out, |r0, rows, band| {
        accum_band(isa, PanelOp::Dot, a, r0, rows, b, n, d, stride, band);
        for ii in 0..rows {
            let nai = na[r0 + ii];
            for (j, o) in band[ii * n..(ii + 1) * n].iter_mut().enumerate() {
                let v = nai + nb[j] - 2.0 * *o;
                *o = if v < 0.0 { 0.0 } else { v };
            }
        }
    });
}

/// Cosine `(m, n)` panel (aux = L2 norms): `1 − dot/(‖a‖‖b‖)` with the
/// zero-vector-at-distance-1 convention, dot in the canonical order.
#[allow(clippy::too_many_arguments)]
pub fn cosine_panel(
    settings: PanelSettings,
    a: &[f32],
    na: &[f32],
    m: usize,
    b: &[f32],
    nb: &[f32],
    n: usize,
    d: usize,
    stride: usize,
    out: &mut [f32],
) {
    debug_assert!(stride >= d);
    debug_assert_eq!(a.len(), m * stride);
    debug_assert_eq!(b.len(), n * stride);
    let isa = effective_isa(settings.isa, d, stride);
    run_bands(settings, m, n, d, out, |r0, rows, band| {
        accum_band(isa, PanelOp::Dot, a, r0, rows, b, n, d, stride, band);
        for ii in 0..rows {
            let ni = na[r0 + ii];
            for (j, o) in band[ii * n..(ii + 1) * n].iter_mut().enumerate() {
                let nj = nb[j];
                *o = if ni == 0.0 || nj == 0.0 { 1.0 } else { 1.0 - *o / (ni * nj) };
            }
        }
    });
}

/// Manhattan `(m, n)` panel: canonical-order `Σ|a−b|`, no epilogue.
pub fn manhattan_panel(
    settings: PanelSettings,
    a: &[f32],
    m: usize,
    b: &[f32],
    n: usize,
    d: usize,
    stride: usize,
    out: &mut [f32],
) {
    debug_assert!(stride >= d);
    debug_assert_eq!(a.len(), m * stride);
    debug_assert_eq!(b.len(), n * stride);
    let isa = effective_isa(settings.isa, d, stride);
    run_bands(settings, m, n, d, out, |r0, rows, band| {
        accum_band(isa, PanelOp::AbsDiff, a, r0, rows, b, n, d, stride, band);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn floats(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.next_f32() * 4.0 - 2.0).collect()
    }

    #[test]
    fn padded_stride_rounds_to_lanes() {
        assert_eq!(padded_stride(0), 0);
        assert_eq!(padded_stride(1), 8);
        assert_eq!(padded_stride(8), 8);
        assert_eq!(padded_stride(9), 16);
        assert_eq!(padded_stride(17), 24);
    }

    #[test]
    fn dot_canonical_exact_on_integers() {
        let mut rng = Pcg64::seeded(11);
        for d in [1usize, 7, 8, 9, 19, 64] {
            let a: Vec<f32> = (0..d).map(|_| rng.next_bounded(17) as f32 - 8.0).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.next_bounded(17) as f32 - 8.0).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert_eq!(dot_canonical(&a, &b), want, "d={d}");
            let l1: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
            assert_eq!(l1_canonical(&a, &b), l1, "d={d}");
        }
    }

    /// The virtual-padding rule: the canonical kernel over the real `d`
    /// prefix must equal itself over the explicitly zero-padded row — the
    /// identity the SIMD panels rely on. Float data on purpose.
    #[test]
    fn canonical_matches_explicit_zero_padding() {
        let mut rng = Pcg64::seeded(12);
        for d in [1usize, 3, 7, 9, 11, 15, 19] {
            let a = floats(&mut rng, d);
            let b = floats(&mut rng, d);
            let (pa, s) = pad_rows(&a, 1, d);
            let (pb, _) = pad_rows(&b, 1, d);
            assert_eq!(s % LANES, 0);
            assert_eq!(
                dot_canonical(&a, &b).to_bits(),
                dot_canonical(&pa, &pb).to_bits(),
                "dot d={d}"
            );
            assert_eq!(
                l1_canonical(&a, &b).to_bits(),
                l1_canonical(&pa, &pb).to_bits(),
                "l1 d={d}"
            );
        }
    }

    /// Dispatched SIMD (when the host has any) and the threaded path must
    /// be bit-identical to the forced-scalar single-thread reference.
    #[test]
    fn simd_and_threads_bit_identical_to_scalar() {
        let mut rng = Pcg64::seeded(13);
        let isa = detect_isa_hw();
        for (m, n, d) in [(5usize, 9usize, 11usize), (8, 8, 16), (1, 3, 7), (13, 4, 33)] {
            let (pa, stride) = pad_rows(&floats(&mut rng, m * d), m, d);
            let (pb, _) = pad_rows(&floats(&mut rng, n * d), n, d);
            let na: Vec<f32> = (0..m)
                .map(|i| pa[i * stride..i * stride + d].iter().map(|x| x * x).sum())
                .collect();
            let nb: Vec<f32> = (0..n)
                .map(|j| pb[j * stride..j * stride + d].iter().map(|x| x * x).sum())
                .collect();
            let mut reference = vec![0.0f32; m * n];
            sqeuclid_panel(
                PanelSettings::scalar(),
                &pa,
                &na,
                m,
                &pb,
                &nb,
                n,
                d,
                stride,
                &mut reference,
            );
            for threads in [1usize, 2, 4] {
                let s = PanelSettings { isa, threads };
                let mut got = vec![0.0f32; m * n];
                sqeuclid_panel(s, &pa, &na, m, &pb, &nb, n, d, stride, &mut got);
                let same = reference
                    .iter()
                    .zip(&got)
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "sqeuclid isa={isa:?} threads={threads} (m={m},n={n},d={d})");

                let mut l1_ref = vec![0.0f32; m * n];
                manhattan_panel(PanelSettings::scalar(), &pa, m, &pb, n, d, stride, &mut l1_ref);
                let mut l1_got = vec![0.0f32; m * n];
                manhattan_panel(s, &pa, m, &pb, n, d, stride, &mut l1_got);
                let same = l1_ref.iter().zip(&l1_got).all(|(x, y)| x.to_bits() == y.to_bits());
                assert!(same, "manhattan isa={isa:?} threads={threads} (m={m},n={n},d={d})");
            }
        }
    }

    #[test]
    fn planned_threads_is_shape_pure_and_bounded() {
        let s = PanelSettings { isa: Isa::Scalar, threads: 8 };
        // tiny panel: spawning is not worth it
        assert_eq!(planned_threads(s, 4, 4, 8), 1);
        // single row can't band
        assert_eq!(planned_threads(s, 1, 10_000, 1024), 1);
        // big panel: capped by the settings budget and by m
        let t = planned_threads(s, 512, 512, 256);
        assert!(t >= 2 && t <= 8, "t={t}");
        assert_eq!(t, planned_threads(s, 512, 512, 256), "pure in the shape");
        assert_eq!(planned_threads(PanelSettings::scalar(), 512, 512, 256), 1);
    }

    #[test]
    fn isa_wire_codes_roundtrip() {
        for isa in [Isa::Scalar, Isa::Avx2, Isa::Neon] {
            assert_eq!(Isa::from_wire_code(isa.wire_code()), Some(isa));
        }
        assert_eq!(Isa::from_wire_code(0), None);
        assert_eq!(Isa::from_wire_code(9), None);
    }

    #[test]
    fn unpadded_stride_degrades_to_scalar() {
        // stride == d with a lane remainder: dispatch must fall back, and
        // the value must match the padded SIMD/scalar result bit-for-bit.
        let mut rng = Pcg64::seeded(14);
        let (m, n, d) = (3usize, 5usize, 11usize);
        let a = floats(&mut rng, m * d);
        let b = floats(&mut rng, n * d);
        assert_eq!(effective_isa(Isa::Avx2, d, d), Isa::Scalar);
        let mut tight = vec![0.0f32; m * n];
        manhattan_panel(PanelSettings::detect(), &a, m, &b, n, d, d, &mut tight);
        let (pa, stride) = pad_rows(&a, m, d);
        let (pb, _) = pad_rows(&b, n, d);
        let mut padded = vec![0.0f32; m * n];
        manhattan_panel(PanelSettings::detect(), &pa, m, &pb, n, d, stride, &mut padded);
        assert_eq!(
            tight.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            padded.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }
}
