//! Symmetric binary distance functions between vectors.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The distance functions supported by the generalized geometric MST.
///
/// All are symmetric; `SqEuclid` is not a metric (no triangle inequality) but
/// induces the same MST as `Euclid` (monotone transform), which is why the
/// hot path uses it — one `sqrt` per *reported* edge instead of per pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MetricKind {
    SqEuclid,
    Euclid,
    Cosine,
    Manhattan,
}

impl MetricKind {
    pub fn name(self) -> &'static str {
        match self {
            MetricKind::SqEuclid => "sqeuclid",
            MetricKind::Euclid => "euclid",
            MetricKind::Cosine => "cosine",
            MetricKind::Manhattan => "manhattan",
        }
    }

    /// The metric whose values this kind *compares* in on the blocked hot
    /// paths: `Euclid` compares in squared form (monotone-equivalent, one
    /// `sqrt` per reported edge instead of per pair); everything else
    /// compares in its own form.
    pub fn compare_form(self) -> Self {
        match self {
            MetricKind::Euclid => MetricKind::SqEuclid,
            other => other,
        }
    }

    /// Parse a metric name. Case-insensitive and whitespace-tolerant, so
    /// config files and CLI flags accept `"L2"`, `" cosine "`, etc.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sqeuclid" | "sq_euclid" | "l2sq" => Some(MetricKind::SqEuclid),
            "euclid" | "euclidean" | "l2" => Some(MetricKind::Euclid),
            "cosine" | "cos" => Some(MetricKind::Cosine),
            "manhattan" | "l1" | "cityblock" => Some(MetricKind::Manhattan),
            _ => None,
        }
    }
}

/// A symmetric binary distance function.
pub trait Metric: Send + Sync {
    /// Distance between two equal-length vectors.
    fn dist(&self, a: &[f32], b: &[f32]) -> f32;

    /// Which kind this is (used for kernel selection and reporting).
    fn kind(&self) -> MetricKind;

    /// Number of distance evaluations performed so far, if counted.
    fn evals(&self) -> u64 {
        0
    }
}

/// Plain (uncounted) metric implementation.
#[derive(Clone, Copy, Debug)]
pub struct PlainMetric(pub MetricKind);

#[inline]
pub fn sq_euclid(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than the naive loop on
    // the pure-Rust d-MST baseline, and deterministic.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

#[inline]
pub fn manhattan(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0.0f32, 0.0f32, 0.0f32);
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na == 0.0 || nb == 0.0 {
        // Zero vectors: define distance 1 (orthogonal-like), symmetric.
        return 1.0;
    }
    1.0 - dot / (na.sqrt() * nb.sqrt())
}

impl Metric for PlainMetric {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        match self.0 {
            MetricKind::SqEuclid => sq_euclid(a, b),
            MetricKind::Euclid => sq_euclid(a, b).sqrt(),
            MetricKind::Cosine => cosine(a, b),
            MetricKind::Manhattan => manhattan(a, b),
        }
    }

    fn kind(&self) -> MetricKind {
        self.0
    }
}

/// Metric wrapper that counts distance evaluations — the work measure used by
/// experiment E2 (work-overhead ratio `2(|P|-1)/|P|`).
#[derive(Clone)]
pub struct CountingMetric {
    inner: PlainMetric,
    count: Arc<AtomicU64>,
}

impl CountingMetric {
    pub fn new(kind: MetricKind) -> Self {
        Self { inner: PlainMetric(kind), count: Arc::new(AtomicU64::new(0)) }
    }

    /// Share the same counter across clones/threads.
    pub fn counter(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.count)
    }

    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }

    /// Record `n` evaluations done externally (e.g. inside an XLA kernel,
    /// where each cheapest-edge call performs N·N distance evaluations).
    pub fn add_external(&self, n: u64) {
        self.count.fetch_add(n, Ordering::Relaxed);
    }
}

impl Metric for CountingMetric {
    #[inline]
    fn dist(&self, a: &[f32], b: &[f32]) -> f32 {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.dist(a, b)
    }

    fn kind(&self) -> MetricKind {
        self.inner.kind()
    }

    fn evals(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: [f32; 5] = [1.0, 2.0, 3.0, 4.0, 5.0];
    const B: [f32; 5] = [2.0, 2.0, 1.0, 4.0, 7.0];

    #[test]
    fn sq_euclid_matches_manual() {
        // diffs: -1, 0, 2, 0, -2 -> 1 + 4 + 4 = 9
        assert_eq!(sq_euclid(&A, &B), 9.0);
        assert_eq!(PlainMetric(MetricKind::Euclid).dist(&A, &B), 3.0);
    }

    #[test]
    fn sq_euclid_unroll_tail() {
        // length 5 exercises the tail (chunks*4 = 4).
        let a = [0.0, 0.0, 0.0, 0.0, 3.0];
        let b = [0.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(sq_euclid(&a, &b), 9.0);
        // length < 4 entirely in tail
        assert_eq!(sq_euclid(&[1.0, 2.0], &[2.0, 0.0]), 5.0);
    }

    #[test]
    fn manhattan_matches_manual() {
        assert_eq!(manhattan(&A, &B), 1.0 + 0.0 + 2.0 + 0.0 + 2.0);
    }

    #[test]
    fn cosine_props() {
        let x = [1.0, 0.0];
        let y = [0.0, 1.0];
        assert!((cosine(&x, &y) - 1.0).abs() < 1e-6, "orthogonal -> 1");
        assert!(cosine(&x, &x).abs() < 1e-6, "self -> 0");
        let z = [-1.0, 0.0];
        assert!((cosine(&x, &z) - 2.0).abs() < 1e-6, "opposite -> 2");
        assert_eq!(cosine(&[0.0, 0.0], &x), 1.0, "zero vector convention");
    }

    #[test]
    fn symmetry_all_kinds() {
        for k in [MetricKind::SqEuclid, MetricKind::Euclid, MetricKind::Cosine, MetricKind::Manhattan] {
            let m = PlainMetric(k);
            assert_eq!(m.dist(&A, &B), m.dist(&B, &A), "{k:?} symmetric");
            if k != MetricKind::Cosine {
                assert_eq!(m.dist(&A, &A), 0.0, "{k:?} identity");
            }
        }
    }

    #[test]
    fn counting_metric_counts() {
        let m = CountingMetric::new(MetricKind::SqEuclid);
        assert_eq!(m.evals(), 0);
        m.dist(&A, &B);
        m.dist(&A, &B);
        assert_eq!(m.evals(), 2);
        m.add_external(100);
        assert_eq!(m.evals(), 102);
        m.reset();
        assert_eq!(m.evals(), 0);
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [MetricKind::SqEuclid, MetricKind::Euclid, MetricKind::Cosine, MetricKind::Manhattan] {
            assert_eq!(MetricKind::parse(k.name()), Some(k));
        }
        assert_eq!(MetricKind::parse("l2"), Some(MetricKind::Euclid));
        assert_eq!(MetricKind::parse("bogus"), None);
    }

    #[test]
    fn compare_form_squares_only_euclid() {
        assert_eq!(MetricKind::Euclid.compare_form(), MetricKind::SqEuclid);
        for k in [MetricKind::SqEuclid, MetricKind::Cosine, MetricKind::Manhattan] {
            assert_eq!(k.compare_form(), k);
        }
    }

    #[test]
    fn kind_parse_case_and_whitespace_insensitive() {
        assert_eq!(MetricKind::parse("L2"), Some(MetricKind::Euclid));
        assert_eq!(MetricKind::parse(" cosine "), Some(MetricKind::Cosine));
        assert_eq!(MetricKind::parse("MANHATTAN"), Some(MetricKind::Manhattan));
        assert_eq!(MetricKind::parse("\tSqEuclid\n"), Some(MetricKind::SqEuclid));
        assert_eq!(MetricKind::parse("Euclidean"), Some(MetricKind::Euclid));
        assert_eq!(MetricKind::parse("  "), None);
        assert_eq!(MetricKind::parse("l2 sq"), None);
    }
}
