//! Blocked pairwise-distance routines (pure Rust), metric-generic.
//!
//! Mirrors the matmul-form decomposition the L1 Pallas kernel uses:
//! `‖x−y‖² = ‖x‖² + ‖y‖² − 2·x·y`. The [`DistanceBlock`] trait generalizes
//! that structure to every [`MetricKind`]: squared Euclidean and cosine share
//! the Gram/dot form with precomputed per-row norms; Manhattan uses a tiled
//! direct loop. Consumers: the blocked dense-Prim hot path, the Borůvka
//! cheapest-edge fallback, the kNN baseline, and the XLA cross-checks.
//!
//! All reductions run in the **canonical lane-split order** defined by
//! [`super::simd`] (8 accumulators, virtual zero padding, fixed reduction
//! tree), so the scalar `row` path, the scalar panel path, and every
//! runtime-dispatched SIMD panel kernel produce bit-identical values. See
//! the `geometry::simd` module docs for the order and the no-fused-ops rule.

use super::metric::MetricKind;
use super::simd::{self, PanelSettings};

/// Squared L2 norm of each row of a row-major `(n, d)` matrix.
pub fn self_norms(data: &[f32], n: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), n * d);
    (0..n)
        .map(|i| {
            let row = &data[i * d..(i + 1) * d];
            row.iter().map(|x| x * x).sum()
        })
        .collect()
}

/// Dense `(m, n)` block of squared Euclidean distances between row-major
/// `a: (m, d)` and `b: (n, d)`, written into `out` (row-major `(m, n)`).
///
/// Uses the matmul-form with precomputed norms and an inner tile over `d` to
/// stay in cache. Clamps tiny negative values (catastrophic cancellation) to
/// zero so downstream `sqrt` never sees negatives.
pub fn pairwise_block(
    a: &[f32],
    na: &[f32],
    m: usize,
    b: &[f32],
    nb: &[f32],
    n: usize,
    d: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(b.len(), n * d);
    debug_assert_eq!(na.len(), m);
    debug_assert_eq!(nb.len(), n);
    debug_assert_eq!(out.len(), m * n);

    // Row-by-row contiguous dot products. Perf note (EXPERIMENTS.md §Perf):
    // the first implementation used an ikj loop with a stride-d walk down
    // b's columns; that thrashed cache badly enough to run *slower than the
    // naive direct-difference loop* at d=128 (2.6 GFLOP/s). The ij loop with
    // an unrolled dot over two contiguous rows vectorizes cleanly and keeps
    // the b tile resident, ~3-4x faster.
    for i in 0..m {
        let arow = &a[i * d..(i + 1) * d];
        let nai = na[i];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * d..(j + 1) * d];
            let v = nai + nb[j] - 2.0 * dot_unrolled(arow, brow);
            *o = if v < 0.0 { 0.0 } else { v };
        }
    }
}

/// Unrolled dot product of two equal-length contiguous slices, in the
/// canonical 8-lane-split accumulation order ([`simd::dot_canonical`]) that
/// every SIMD panel kernel reproduces bit-for-bit.
#[inline]
pub fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    simd::dot_canonical(a, b)
}

/// Unrolled Manhattan (L1) distance of two contiguous rows, in the
/// canonical 8-lane-split accumulation order ([`simd::l1_canonical`]).
#[inline]
pub fn manhattan_unrolled(a: &[f32], b: &[f32]) -> f32 {
    simd::l1_canonical(a, b)
}

/// Convenience: full `(n, n)` self-distance matrix (squared Euclidean).
pub fn pairwise_self(data: &[f32], n: usize, d: usize) -> Vec<f32> {
    let norms = self_norms(data, n, d);
    let mut out = vec![0.0; n * n];
    pairwise_block(data, &norms, n, data, &norms, n, d, &mut out);
    out
}

/// Metric-generic blocked distance computation over one shared row-major
/// point matrix.
///
/// The protocol is two-phase: [`prepare`](DistanceBlock::prepare) computes
/// per-row auxiliary values once (norms for the Gram-form metrics, nothing
/// for Manhattan), then [`row`](DistanceBlock::row) produces a distance row
/// from a pivot to an arbitrary index list — the shape the blocked dense-Prim
/// hot loop and the cheapest-edge step both consume. All implementations
/// preserve the value-level conventions of the scalar [`super::Metric`]
/// path (clamping, the cosine zero-vector rule), so the strict `(w, u, v)`
/// edge order downstream sees the same comparisons.
pub trait DistanceBlock: Send + Sync {
    /// Which metric this block computes. For `Euclid` the *comparison* form
    /// is still squared (monotone-equivalent); see [`compare_form_is_squared`].
    fn kind(&self) -> MetricKind;

    /// True when [`row`](DistanceBlock::row) emits squared-Euclidean values
    /// that callers must `sqrt` before reporting `Euclid` edge weights.
    fn compare_form_is_squared(&self) -> bool {
        false
    }

    /// Per-row auxiliary values over the `(n, d)` matrix (may be empty).
    fn prepare(&self, data: &[f32], n: usize, d: usize) -> Vec<f32>;

    /// Distances from row `i` to each row in `js`, written to
    /// `out[..js.len()]`. `aux` is the result of [`prepare`](Self::prepare)
    /// over the same matrix.
    fn row(&self, data: &[f32], d: usize, aux: &[f32], i: usize, js: &[u32], out: &mut [f32]);

    /// Dense `(is.len(), js.len())` block, row-major. Default: one
    /// [`row`](Self::row) call per pivot.
    fn block(
        &self,
        data: &[f32],
        d: usize,
        aux: &[f32],
        is: &[u32],
        js: &[u32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), is.len() * js.len());
        let w = js.len();
        for (k, &i) in is.iter().enumerate() {
            self.row(data, d, aux, i as usize, js, &mut out[k * w..(k + 1) * w]);
        }
    }

    /// Dense `(m, n)` block between two *packed panels* — rows gathered
    /// contiguously out of one prepared matrix at `stride` floats per row
    /// (`stride ≥ d`; the pad region `d..stride` of every row must be
    /// **zero** — see [`simd::pad_rows`]), with `aux_a`/`aux_b` the
    /// matching slices of that matrix's [`prepare`](Self::prepare) output.
    /// Written row-major into `out`.
    ///
    /// Contract: each element must be **bit-identical** to what
    /// [`row`](Self::row) computes for the same underlying pair (same
    /// arithmetic, same canonical accumulation order, same clamping), so
    /// kernels may mix the row and panel paths without perturbing the
    /// strict `(w, u, v)` edge order. The default implementation stacks the
    /// two panels into a temporary matrix and reuses `row`; the concrete
    /// blocks override it with the runtime-dispatched SIMD panel kernels
    /// of [`simd`], which honor the same order for any stride that fits
    /// whole lanes (`stride % simd::LANES == 0`, `stride ≥` the padded
    /// width) and degrade to the canonical scalar loop otherwise.
    #[allow(clippy::too_many_arguments)]
    fn panel_block(
        &self,
        a: &[f32],
        aux_a: &[f32],
        m: usize,
        b: &[f32],
        aux_b: &[f32],
        n: usize,
        d: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        debug_assert!(stride >= d);
        debug_assert_eq!(a.len(), m * stride);
        debug_assert_eq!(b.len(), n * stride);
        debug_assert_eq!(out.len(), m * n);
        let mut data = Vec::with_capacity((m + n) * d);
        for i in 0..m {
            data.extend_from_slice(&a[i * stride..i * stride + d]);
        }
        for j in 0..n {
            data.extend_from_slice(&b[j * stride..j * stride + d]);
        }
        let mut aux = Vec::with_capacity(aux_a.len() + aux_b.len());
        aux.extend_from_slice(aux_a);
        aux.extend_from_slice(aux_b);
        let js: Vec<u32> = (m as u32..(m + n) as u32).collect();
        for i in 0..m {
            self.row(&data, d, &aux, i, &js, &mut out[i * n..(i + 1) * n]);
        }
    }
}

/// Gram/dot-form squared Euclidean (optionally `sqrt`ed to true Euclidean at
/// emission time — comparisons stay in squared form either way).
pub struct SqEuclidBlock {
    /// Report `Euclid` as the metric kind (weights get `sqrt` at edge
    /// emission by the kernels; `row` output stays squared).
    pub euclid: bool,
    /// Panel-kernel dispatch (ISA + thread budget); speed-only — every
    /// setting is bit-identical.
    panel: PanelSettings,
}

impl SqEuclidBlock {
    pub fn new(euclid: bool, panel: PanelSettings) -> Self {
        Self { euclid, panel }
    }
}

impl DistanceBlock for SqEuclidBlock {
    fn kind(&self) -> MetricKind {
        if self.euclid {
            MetricKind::Euclid
        } else {
            MetricKind::SqEuclid
        }
    }

    fn compare_form_is_squared(&self) -> bool {
        self.euclid
    }

    fn prepare(&self, data: &[f32], n: usize, d: usize) -> Vec<f32> {
        self_norms(data, n, d)
    }

    fn row(&self, data: &[f32], d: usize, aux: &[f32], i: usize, js: &[u32], out: &mut [f32]) {
        debug_assert!(out.len() >= js.len());
        let arow = &data[i * d..(i + 1) * d];
        let nai = aux[i];
        for (k, &j) in js.iter().enumerate() {
            let j = j as usize;
            let v = nai + aux[j] - 2.0 * dot_unrolled(arow, &data[j * d..(j + 1) * d]);
            out[k] = if v < 0.0 { 0.0 } else { v };
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn panel_block(
        &self,
        a: &[f32],
        aux_a: &[f32],
        m: usize,
        b: &[f32],
        aux_b: &[f32],
        n: usize,
        d: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        // `na + nb - 2·dot` with the same clamp and the same canonical dot
        // order as `row`, dispatched to the SIMD micro-kernels when the
        // stride fits whole lanes — bit-identical per element either way.
        simd::sqeuclid_panel(self.panel, a, aux_a, m, b, aux_b, n, d, stride, out);
    }
}

/// Gram/dot-form cosine distance with precomputed L2 norms:
/// `1 − x·y / (‖x‖‖y‖)`; zero vectors are at distance 1 from everything
/// (matching the scalar [`super::metric::cosine`] convention).
pub struct CosineBlock {
    panel: PanelSettings,
}

impl CosineBlock {
    pub fn new(panel: PanelSettings) -> Self {
        Self { panel }
    }
}

impl DistanceBlock for CosineBlock {
    fn kind(&self) -> MetricKind {
        MetricKind::Cosine
    }

    fn prepare(&self, data: &[f32], n: usize, d: usize) -> Vec<f32> {
        self_norms(data, n, d).into_iter().map(f32::sqrt).collect()
    }

    fn row(&self, data: &[f32], d: usize, aux: &[f32], i: usize, js: &[u32], out: &mut [f32]) {
        debug_assert!(out.len() >= js.len());
        let arow = &data[i * d..(i + 1) * d];
        let ni = aux[i];
        for (k, &j) in js.iter().enumerate() {
            let j = j as usize;
            let nj = aux[j];
            out[k] = if ni == 0.0 || nj == 0.0 {
                1.0
            } else {
                1.0 - dot_unrolled(arow, &data[j * d..(j + 1) * d]) / (ni * nj)
            };
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn panel_block(
        &self,
        a: &[f32],
        aux_a: &[f32],
        m: usize,
        b: &[f32],
        aux_b: &[f32],
        n: usize,
        d: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        simd::cosine_panel(self.panel, a, aux_a, m, b, aux_b, n, d, stride, out);
    }
}

/// Tiled direct Manhattan (L1): no useful Gram form exists, so this is a
/// cache-friendly unrolled direct loop (SIMD absolute-difference
/// accumulation on the panel path).
pub struct ManhattanBlock {
    panel: PanelSettings,
}

impl ManhattanBlock {
    pub fn new(panel: PanelSettings) -> Self {
        Self { panel }
    }
}

impl DistanceBlock for ManhattanBlock {
    fn kind(&self) -> MetricKind {
        MetricKind::Manhattan
    }

    fn prepare(&self, _data: &[f32], _n: usize, _d: usize) -> Vec<f32> {
        Vec::new()
    }

    fn row(&self, data: &[f32], d: usize, _aux: &[f32], i: usize, js: &[u32], out: &mut [f32]) {
        debug_assert!(out.len() >= js.len());
        let arow = &data[i * d..(i + 1) * d];
        for (k, &j) in js.iter().enumerate() {
            let j = j as usize;
            out[k] = manhattan_unrolled(arow, &data[j * d..(j + 1) * d]);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn panel_block(
        &self,
        a: &[f32],
        _aux_a: &[f32],
        m: usize,
        b: &[f32],
        _aux_b: &[f32],
        n: usize,
        d: usize,
        stride: usize,
        out: &mut [f32],
    ) {
        simd::manhattan_panel(self.panel, a, m, b, n, d, stride, out);
    }
}

/// Factory: the blocked implementation for a metric kind, with
/// environment-driven panel settings ([`PanelSettings::detect`] — hardware
/// ISA unless `DEMST_SIMD=off`, all available cores unless
/// `DEMST_PANEL_THREADS` caps them).
pub fn distance_block(kind: MetricKind) -> Box<dyn DistanceBlock> {
    distance_block_with(kind, PanelSettings::detect())
}

/// Factory with explicit panel settings (the engine resolves them from
/// `RunConfig`; tests pin [`PanelSettings::scalar`] for the canonical
/// reference path). Settings are speed-only — outputs are bit-identical
/// across every ISA and thread count.
pub fn distance_block_with(kind: MetricKind, panel: PanelSettings) -> Box<dyn DistanceBlock> {
    match kind {
        MetricKind::SqEuclid => Box::new(SqEuclidBlock::new(false, panel)),
        MetricKind::Euclid => Box::new(SqEuclidBlock::new(true, panel)),
        MetricKind::Cosine => Box::new(CosineBlock::new(panel)),
        MetricKind::Manhattan => Box::new(ManhattanBlock::new(panel)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::metric::{cosine, manhattan, sq_euclid, Metric, PlainMetric};
    use crate::util::prng::Pcg64;

    #[test]
    fn self_norms_basic() {
        let data = [3.0, 4.0, 0.0, 1.0]; // rows (3,4), (0,1)
        assert_eq!(self_norms(&data, 2, 2), vec![25.0, 1.0]);
    }

    #[test]
    fn block_matches_direct() {
        let mut rng = Pcg64::seeded(1);
        let (m, n, d) = (7, 9, 13);
        let a: Vec<f32> = (0..m * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let b: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let na = self_norms(&a, m, d);
        let nb = self_norms(&b, n, d);
        let mut out = vec![0.0; m * n];
        pairwise_block(&a, &na, m, &b, &nb, n, d, &mut out);
        for i in 0..m {
            for j in 0..n {
                let direct = sq_euclid(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]);
                let got = out[i * n + j];
                assert!(
                    (direct - got).abs() <= 1e-4 * (1.0 + direct),
                    "({i},{j}): direct={direct} got={got}"
                );
            }
        }
    }

    #[test]
    fn self_matrix_diagonal_zeroish_and_symmetric() {
        let mut rng = Pcg64::seeded(2);
        let (n, d) = (12, 5);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32()).collect();
        let m = pairwise_self(&data, n, d);
        for i in 0..n {
            assert!(m[i * n + i] <= 1e-5, "diag[{i}]={}", m[i * n + i]);
            for j in 0..n {
                assert!((m[i * n + j] - m[j * n + i]).abs() <= 1e-5);
                assert!(m[i * n + j] >= 0.0, "non-negative after clamp");
            }
        }
    }

    /// Integer coordinates keep every arithmetic path exact in f32, so the
    /// blocked rows must agree with the scalar metrics bit-for-bit
    /// (sq-euclid, manhattan) or to float-identical operation order (cosine,
    /// whose norms/dot are exact on integer inputs).
    #[test]
    fn distance_block_rows_match_scalar_metrics() {
        let mut rng = Pcg64::seeded(3);
        let (n, d) = (23, 9);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(17) as f32 - 8.0).collect();
        let js: Vec<u32> = (0..n as u32).collect();
        let mut out = vec![0.0f32; n];
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            let blk = distance_block(kind);
            assert_eq!(blk.kind(), kind);
            let aux = blk.prepare(&data, n, d);
            for i in 0..n {
                blk.row(&data, d, &aux, i, &js, &mut out);
                for j in 0..n {
                    let a = &data[i * d..(i + 1) * d];
                    let b = &data[j * d..(j + 1) * d];
                    let want = match kind {
                        MetricKind::SqEuclid | MetricKind::Euclid => sq_euclid(a, b),
                        MetricKind::Cosine => cosine(a, b),
                        MetricKind::Manhattan => manhattan(a, b),
                    };
                    assert_eq!(out[j], want, "{kind:?} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn euclid_block_is_squared_compare_form() {
        let blk = distance_block(MetricKind::Euclid);
        assert!(blk.compare_form_is_squared());
        assert_eq!(blk.kind(), MetricKind::Euclid);
        assert!(!distance_block(MetricKind::SqEuclid).compare_form_is_squared());
        assert!(!distance_block(MetricKind::Cosine).compare_form_is_squared());
    }

    #[test]
    fn cosine_block_zero_vector_convention() {
        // row 0 is the zero vector; scalar convention says distance 1.
        let data = vec![0.0, 0.0, 1.0, 2.0, 3.0, -1.0];
        let blk = CosineBlock::new(PanelSettings::scalar());
        let aux = blk.prepare(&data, 3, 2);
        let js = [0u32, 1, 2];
        let mut out = [0.0f32; 3];
        blk.row(&data, 2, &aux, 0, &js, &mut out);
        assert_eq!(out, [1.0, 1.0, 1.0]);
        blk.row(&data, 2, &aux, 1, &js, &mut out);
        assert_eq!(out[0], 1.0, "against the zero vector");
        assert!(out[1].abs() < 1e-6, "self distance ~0");
    }

    #[test]
    fn block_default_impl_matches_rows() {
        let mut rng = Pcg64::seeded(4);
        let (n, d) = (11, 6);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let blk = distance_block(MetricKind::SqEuclid);
        let aux = blk.prepare(&data, n, d);
        let is: Vec<u32> = vec![2, 5, 7];
        let js: Vec<u32> = (0..n as u32).collect();
        let mut tile = vec![0.0f32; is.len() * n];
        blk.block(&data, d, &aux, &is, &js, &mut tile);
        let mut row = vec![0.0f32; n];
        for (k, &i) in is.iter().enumerate() {
            blk.row(&data, d, &aux, i as usize, &js, &mut row);
            assert_eq!(&tile[k * n..(k + 1) * n], row.as_slice(), "pivot {i}");
        }
    }

    /// The panel path must be bit-identical to the row path — float data on
    /// purpose, so any drift in operation order or clamping fails loudly.
    /// Runs the dispatched (possibly SIMD) settings over a lane-padded
    /// panel *and* the tight `stride == d` layout (scalar degrade path),
    /// plus a threaded plan — all must match the rows to the bit.
    #[test]
    fn panel_block_bit_identical_to_rows() {
        let mut rng = Pcg64::seeded(6);
        let (n, d) = (26, 11);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let is: Vec<u32> = (0..n as u32).filter(|i| i % 3 == 0).collect();
        let js: Vec<u32> = (0..n as u32).filter(|i| i % 3 != 0).collect();
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            for (settings, padded) in [
                (PanelSettings::scalar(), false),
                (PanelSettings::detect(), true),
                (PanelSettings { threads: 4, ..PanelSettings::detect() }, true),
            ] {
                let blk = distance_block_with(kind, settings);
                let aux = blk.prepare(&data, n, d);
                let stride = if padded { simd::padded_stride(d) } else { d };
                // pack the two panels at the chosen stride (pad region zero)
                let pack = |ids: &[u32]| -> (Vec<f32>, Vec<f32>) {
                    let mut p = vec![0.0f32; ids.len() * stride];
                    for (k, &g) in ids.iter().enumerate() {
                        p[k * stride..k * stride + d]
                            .copy_from_slice(&data[g as usize * d..(g as usize + 1) * d]);
                    }
                    let a: Vec<f32> = if aux.is_empty() {
                        Vec::new()
                    } else {
                        ids.iter().map(|&g| aux[g as usize]).collect()
                    };
                    (p, a)
                };
                let (pa, aa) = pack(&is);
                let (pb, ab) = pack(&js);
                let mut tile = vec![0.0f32; is.len() * js.len()];
                blk.panel_block(&pa, &aa, is.len(), &pb, &ab, js.len(), d, stride, &mut tile);
                let mut row = vec![0.0f32; js.len()];
                for (k, &i) in is.iter().enumerate() {
                    blk.row(&data, d, &aux, i as usize, &js, &mut row);
                    assert_eq!(
                        &tile[k * js.len()..(k + 1) * js.len()],
                        row.as_slice(),
                        "{kind:?} stride={stride} pivot {i}: panel path must be bit-identical"
                    );
                }
            }
        }
    }

    #[test]
    fn blocked_rows_consistent_with_plain_metric_tolerance() {
        // Continuous data: dot-form vs diff-form agree to relative tolerance.
        let mut rng = Pcg64::seeded(5);
        let (n, d) = (16, 24);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let js: Vec<u32> = (0..n as u32).collect();
        let mut out = vec![0.0f32; n];
        for kind in [MetricKind::SqEuclid, MetricKind::Cosine, MetricKind::Manhattan] {
            let blk = distance_block(kind);
            let aux = blk.prepare(&data, n, d);
            let scalar = PlainMetric(kind);
            for i in 0..n {
                blk.row(&data, d, &aux, i, &js, &mut out);
                for j in 0..n {
                    let want = scalar.dist(&data[i * d..(i + 1) * d], &data[j * d..(j + 1) * d]);
                    assert!(
                        (out[j] - want).abs() <= 1e-4 * (1.0 + want.abs()),
                        "{kind:?} ({i},{j}): blocked={} scalar={want}",
                        out[j]
                    );
                }
            }
        }
    }
}
