//! Blocked pairwise-distance routines (pure Rust).
//!
//! Mirrors the matmul-form decomposition the L1 Pallas kernel uses:
//! `‖x−y‖² = ‖x‖² + ‖y‖² − 2·x·y`. Used by the Rust cheapest-edge fallback,
//! the kNN baseline, and as a cross-check for the XLA pairwise executable.

/// Squared L2 norm of each row of a row-major `(n, d)` matrix.
pub fn self_norms(data: &[f32], n: usize, d: usize) -> Vec<f32> {
    debug_assert_eq!(data.len(), n * d);
    (0..n)
        .map(|i| {
            let row = &data[i * d..(i + 1) * d];
            row.iter().map(|x| x * x).sum()
        })
        .collect()
}

/// Dense `(m, n)` block of squared Euclidean distances between row-major
/// `a: (m, d)` and `b: (n, d)`, written into `out` (row-major `(m, n)`).
///
/// Uses the matmul-form with precomputed norms and an inner tile over `d` to
/// stay in cache. Clamps tiny negative values (catastrophic cancellation) to
/// zero so downstream `sqrt` never sees negatives.
pub fn pairwise_block(
    a: &[f32],
    na: &[f32],
    m: usize,
    b: &[f32],
    nb: &[f32],
    n: usize,
    d: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(b.len(), n * d);
    debug_assert_eq!(na.len(), m);
    debug_assert_eq!(nb.len(), n);
    debug_assert_eq!(out.len(), m * n);

    // Row-by-row contiguous dot products. Perf note (EXPERIMENTS.md §Perf):
    // the first implementation used an ikj loop with a stride-d walk down
    // b's columns; that thrashed cache badly enough to run *slower than the
    // naive direct-difference loop* at d=128 (2.6 GFLOP/s). The ij loop with
    // a 4-way unrolled dot over two contiguous rows vectorizes cleanly and
    // keeps the b tile resident, ~3-4x faster.
    for i in 0..m {
        let arow = &a[i * d..(i + 1) * d];
        let nai = na[i];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let brow = &b[j * d..(j + 1) * d];
            let v = nai + nb[j] - 2.0 * dot_unrolled(arow, brow);
            *o = if v < 0.0 { 0.0 } else { v };
        }
    }
}

/// 4-way unrolled dot product of two equal-length contiguous slices.
#[inline]
pub fn dot_unrolled(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// Convenience: full `(n, n)` self-distance matrix (squared Euclidean).
pub fn pairwise_self(data: &[f32], n: usize, d: usize) -> Vec<f32> {
    let norms = self_norms(data, n, d);
    let mut out = vec![0.0; n * n];
    pairwise_block(data, &norms, n, data, &norms, n, d, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::metric::sq_euclid;
    use crate::util::prng::Pcg64;

    #[test]
    fn self_norms_basic() {
        let data = [3.0, 4.0, 0.0, 1.0]; // rows (3,4), (0,1)
        assert_eq!(self_norms(&data, 2, 2), vec![25.0, 1.0]);
    }

    #[test]
    fn block_matches_direct() {
        let mut rng = Pcg64::seeded(1);
        let (m, n, d) = (7, 9, 13);
        let a: Vec<f32> = (0..m * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let b: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let na = self_norms(&a, m, d);
        let nb = self_norms(&b, n, d);
        let mut out = vec![0.0; m * n];
        pairwise_block(&a, &na, m, &b, &nb, n, d, &mut out);
        for i in 0..m {
            for j in 0..n {
                let direct = sq_euclid(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]);
                let got = out[i * n + j];
                assert!(
                    (direct - got).abs() <= 1e-4 * (1.0 + direct),
                    "({i},{j}): direct={direct} got={got}"
                );
            }
        }
    }

    #[test]
    fn self_matrix_diagonal_zeroish_and_symmetric() {
        let mut rng = Pcg64::seeded(2);
        let (n, d) = (12, 5);
        let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32()).collect();
        let m = pairwise_self(&data, n, d);
        for i in 0..n {
            assert!(m[i * n + i] <= 1e-5, "diag[{i}]={}", m[i * n + i]);
            for j in 0..n {
                assert!((m[i * n + j] - m[j * n + i]).abs() <= 1e-5);
                assert!(m[i * n + j] >= 0.0, "non-negative after clamp");
            }
        }
    }
}
