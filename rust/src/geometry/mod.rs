//! Distance functions ("generalized geometric" metrics per the paper) and
//! blocked distance routines.
//!
//! The paper's graph weights are `w({x,y}) = d(x⃗, y⃗)` for a symmetric binary
//! distance function. Everything downstream (MST, decomposition, dendrogram)
//! is metric-agnostic. The high-performance paths run through the
//! [`DistanceBlock`] trait: a metric-generic blocked kernel family in the
//! same Gram/dot form the L1 Pallas kernel computes (squared Euclidean and
//! cosine via precomputed norms + dot products, Manhattan via a tiled direct
//! loop), so every metric gets the cache-blocked hot path. The panel form of
//! that path dispatches at runtime to the register-tiled SIMD micro-kernels
//! in [`simd`] — bit-identical to the scalar reference by a shared canonical
//! accumulation order.

pub mod metric;
pub mod blocked;
pub mod simd;

pub use blocked::{
    distance_block, distance_block_with, pairwise_block, self_norms, DistanceBlock,
};
pub use metric::{CountingMetric, Metric, MetricKind};
pub use simd::{Isa, PanelSettings};
