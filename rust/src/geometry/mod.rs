//! Distance functions ("generalized geometric" metrics per the paper) and
//! blocked distance routines.
//!
//! The paper's graph weights are `w({x,y}) = d(x⃗, y⃗)` for a symmetric binary
//! distance function. Everything downstream (MST, decomposition, dendrogram)
//! is metric-agnostic; high-performance paths specialize squared Euclidean
//! because the L1 Pallas kernel computes it in matmul form.

pub mod metric;
pub mod blocked;

pub use metric::{CountingMetric, Metric, MetricKind};
pub use blocked::{pairwise_block, self_norms};
