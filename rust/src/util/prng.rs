//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` for seeding, `Pcg64` (PCG-XSL-RR 128/64) as the workhorse
//! generator. No external `rand` crate is available offline; these are
//! standard, well-tested constructions with reference test vectors below.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
///
/// Reference: Steele, Lea, Flood, "Fast Splittable Pseudorandom Number
/// Generators" (OOPSLA 2014); constants from the public-domain reference code.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-low + random-rotate
/// output. Period 2^128, passes BigCrush. Public-domain construction
/// (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from full 128-bit state and stream.
    pub fn new(state: u128, stream: u128) -> Self {
        let mut pcg = Self { state: 0, inc: (stream << 1) | 1 };
        pcg.state = pcg.inc.wrapping_add(state);
        pcg.step();
        pcg
    }

    /// Construct deterministically from a single 64-bit seed.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let a = sm.next_u64() as u128;
        let b = sm.next_u64() as u128;
        let c = sm.next_u64() as u128;
        let d = sm.next_u64() as u128;
        Self::new((a << 64) | b, (c << 64) | d)
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn split(&mut self) -> Self {
        let s = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        let t = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        Self::new(s, t)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method, simplified).
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // widening-multiply rejection sampling
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value not kept; callers
    /// in this codebase draw in bulk so the 2× cost is irrelevant).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.next_bounded((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_bounded((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn splitmix_known_value_seed0() {
        // First output for seed 0 is the finalizer applied to GOLDEN_GAMMA.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn pcg_determinism_and_split_independence() {
        let mut a = Pcg64::seeded(99);
        let mut b = Pcg64::seeded(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = a.split();
        let mut d = a.split();
        // Children differ from each other and from parent stream.
        let cs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        let ds: Vec<u64> = (0..8).map(|_| d.next_u64()).collect();
        assert_ne!(cs, ds);
    }

    #[test]
    fn bounded_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.next_bounded(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg64::seeded(3);
        let mut lo = f64::MAX;
        let mut hi = f64::MIN;
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            lo = lo.min(v);
            hi = hi.max(v);
        }
        assert!(lo < 0.01 && hi > 0.99, "spans the interval: lo={lo} hi={hi}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seeded(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.next_gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(17);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(t.iter().all(|&i| i < 50));
    }
}
