//! Wallclock timing helpers used by the coordinator metrics and the bench
//! harness.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, duration).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed())
}

/// Pretty-print a duration with an adaptive unit.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{:.3}s", s)
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}µs", s * 1e6)
    } else {
        format!("{}ns", d.as_nanos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_result() {
        let (v, d) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // smoke: no panic
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.000ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7.000µs");
        assert_eq!(fmt_duration(Duration::from_nanos(3)), "3ns");
    }

    #[test]
    fn stopwatch_restart_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        let first = sw.restart();
        assert!(first.as_millis() >= 1);
        let second = sw.elapsed();
        assert!(second <= first + Duration::from_millis(50));
    }
}
