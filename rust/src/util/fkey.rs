//! Total ordering for `f32` edge weights.
//!
//! MST uniqueness (assumed by the paper) requires a strict total order on
//! edges. We order lexicographically by `(weight, u, v)` with weights compared
//! via IEEE-754 `total_cmp`, so equal-weight edges are still strictly ordered
//! and every algorithm in the crate (Kruskal / Prim / Borůvka / SLINK /
//! decomposed) agrees on the same unique MSF.

use std::cmp::Ordering;

/// A non-NaN f32 wrapper with total order. Constructing from NaN panics —
/// distances in this crate are always finite or `+inf` sentinels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct F32Key(pub f32);

impl F32Key {
    #[inline]
    pub fn new(v: f32) -> Self {
        debug_assert!(!v.is_nan(), "NaN edge weight");
        Self(v)
    }
}

impl Eq for F32Key {}

impl PartialOrd for F32Key {
    #[inline]
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F32Key {
    #[inline]
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Compare two `(w, u, v)` triples lexicographically: the canonical strict
/// edge order used across the crate.
#[inline]
pub fn edge_cmp(w1: f32, u1: u32, v1: u32, w2: f32, u2: u32, v2: u32) -> Ordering {
    w1.total_cmp(&w2).then(u1.cmp(&u2)).then(v1.cmp(&v2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_order_handles_equal_weights() {
        assert_eq!(edge_cmp(1.0, 0, 1, 1.0, 0, 2), Ordering::Less);
        assert_eq!(edge_cmp(1.0, 2, 1, 1.0, 0, 2), Ordering::Greater);
        assert_eq!(edge_cmp(1.0, 0, 1, 1.0, 0, 1), Ordering::Equal);
        assert_eq!(edge_cmp(0.5, 9, 9, 1.0, 0, 0), Ordering::Less);
    }

    #[test]
    fn f32key_sorts_with_infinity() {
        let mut ks = vec![F32Key::new(f32::INFINITY), F32Key::new(0.0), F32Key::new(-1.0)];
        ks.sort();
        assert_eq!(ks[0].0, -1.0);
        assert_eq!(ks[2].0, f32::INFINITY);
    }

    #[test]
    fn negative_zero_ordered_before_positive_zero() {
        // total_cmp: -0.0 < +0.0 — fine for determinism, just document it.
        assert_eq!(F32Key::new(-0.0).cmp(&F32Key::new(0.0)), Ordering::Less);
    }
}
