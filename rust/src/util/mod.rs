//! Small self-contained substrates: PRNG, timing, float total-ordering,
//! a property-testing harness, and misc helpers.
//!
//! The offline vendor set has no `rand`, `criterion`, or `proptest`, so these
//! are first-class implementations rather than wrappers.

pub mod prng;
pub mod timer;
pub mod fkey;
pub mod proptest;

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    div_ceil(a, b) * b
}

/// Human-readable byte count (binary units).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Human-readable count with SI suffix (decimal units).
pub fn human_count(c: u64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "G", "T"];
    let mut v = c as f64;
    let mut u = 0;
    while v >= 1000.0 && u + 1 < UNITS.len() {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{}", c)
    } else {
        format!("{:.2}{}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
        assert_eq!(div_ceil(8, 4), 2);
    }

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_count_units() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1500), "1.50K");
        assert_eq!(human_count(2_000_000), "2.00M");
    }
}
