//! A small property-based testing harness (the vendor set has no `proptest`).
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use demst::util::proptest::{Runner, Gen};
//! let mut r = Runner::new("vec reverse twice is identity", 0xDEADBEEF, 100);
//! r.run(|g| {
//!     let xs = g.vec_u32(0..64, 1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```
//!
//! Each case gets a deterministic child PRNG; on failure the harness reports
//! the case index and seed so the exact case can be replayed with
//! `Runner::replay`.

use super::prng::Pcg64;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    /// usize in `[lo, hi)`.
    pub fn usize_in(&mut self, r: std::ops::Range<usize>) -> usize {
        assert!(r.start < r.end);
        r.start + self.rng.next_bounded((r.end - r.start) as u64) as usize
    }

    /// f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    /// Vector of `len` u32 values each in `range`.
    pub fn vec_u32(&mut self, range: std::ops::Range<u32>, len: usize) -> Vec<u32> {
        (0..len)
            .map(|_| range.start + self.rng.next_bounded((range.end - range.start) as u64) as u32)
            .collect()
    }

    /// Vector of `len` f32 values in `[lo, hi)`.
    pub fn vec_f32(&mut self, lo: f32, hi: f32, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// A random point set: `n` points in `d` dims, coords in [-scale, scale).
    pub fn points(&mut self, n: usize, d: usize, scale: f32) -> Vec<f32> {
        self.vec_f32(-scale, scale, n * d)
    }

    /// Random boolean with probability `p` of true.
    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }
}

/// Drives `cases` executions of a property with deterministic seeds.
pub struct Runner {
    name: &'static str,
    seed: u64,
    cases: u32,
}

impl Runner {
    pub fn new(name: &'static str, seed: u64, cases: u32) -> Self {
        Self { name, seed, cases }
    }

    /// Run the property across all cases; panics (with replay info) on the
    /// first failing case.
    pub fn run(&mut self, mut prop: impl FnMut(&mut Gen)) {
        for case in 0..self.cases {
            let mut root = Pcg64::seeded(self.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut g = Gen { rng: root.split() };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
            if let Err(e) = result {
                eprintln!(
                    "property '{}' failed at case {}/{} (seed=0x{:X}); replay with Runner::replay(name, 0x{:X}, {})",
                    self.name, case, self.cases, self.seed, self.seed, case
                );
                std::panic::resume_unwind(e);
            }
        }
    }

    /// Re-run a single failing case.
    pub fn replay(name: &'static str, seed: u64, case: u32, mut prop: impl FnMut(&mut Gen)) {
        let mut r = Runner { name, seed, cases: 1 };
        let _ = &mut r;
        let mut root = Pcg64::seeded(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen { rng: root.split() };
        prop(&mut g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_runs_all_cases() {
        let mut count = 0;
        Runner::new("count", 1, 25).run(|_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn gen_ranges_respected() {
        Runner::new("ranges", 2, 50).run(|g| {
            let u = g.usize_in(3..9);
            assert!((3..9).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec_u32(5..7, 10);
            assert!(v.iter().all(|&x| x == 5 || x == 6));
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        Runner::new("fail", 3, 10).run(|g| {
            let v = g.usize_in(0..100);
            assert!(v < 90, "expected failure somewhere in 10 cases");
        });
    }
}
