//! The shard manifest: the small, host-portable description of one
//! partitioned dataset — everything a leader needs to *plan* a sharded run
//! and everything a worker needs to *verify* the shard files it loaded.
//!
//! The manifest is TOML-lite (the same dialect as run configs) so it is
//! human-inspectable and parseable by the existing `config::toml_lite`
//! machinery. It records the run shape (`n`, `d`, `metric`), the partition
//! provenance (`strategy`, `seed`), and per shard: the file name, row
//! count, the subset's global ids (compact ascending ranges), and the
//! FNV-1a 64 digest of the shard file's contents.
//!
//! Crucially the manifest carries **ids, never vectors**: a leader planning
//! from a manifest holds the full partition layout while the vector payload
//! stays on the worker hosts — which is what lets a sharded run assert
//! `leader_ingest_bytes == 0`.
//!
//! [`Manifest::fingerprint`] is a 64-bit digest over the layout and shard
//! digests; the leader announces it in the v2 `Setup` frame so a worker
//! that loaded shards cut from a *different* partition run fails the
//! handshake loudly instead of computing a wrong tree.

use super::digest::{digest_hex, fnv1a64_update, parse_digest_hex, FNV_OFFSET};
use crate::config::toml_lite::{parse_toml, TomlValue};
use crate::decomp::PartitionStrategy;
use crate::geometry::MetricKind;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Manifest format version.
pub const MANIFEST_VERSION: i64 = 1;

/// One shard's manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardEntry {
    /// partition subset index
    pub part: u32,
    /// shard file name, relative to the manifest's directory
    pub file: String,
    /// ascending global ids of the subset
    pub ids: Vec<u32>,
    /// FNV-1a 64 digest of the shard file's full contents
    pub digest: u64,
}

/// A parsed (or freshly written) shard manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    pub name: String,
    pub n: usize,
    pub d: usize,
    pub metric: MetricKind,
    pub strategy: PartitionStrategy,
    pub seed: u64,
    pub shards: Vec<ShardEntry>,
    /// directory the manifest was loaded from (shard files resolve against
    /// it); empty for freshly built, not-yet-written manifests
    pub dir: PathBuf,
}

impl Manifest {
    /// The partition layout: per-subset ascending global-id lists, indexed
    /// by subset — exactly the `ExecPlan::parts` shape.
    pub fn layout(&self) -> Vec<Vec<u32>> {
        self.shards.iter().map(|s| s.ids.clone()).collect()
    }

    /// Number of partition subsets.
    pub fn parts(&self) -> usize {
        self.shards.len()
    }

    /// Resolve shard `k`'s file path against the manifest directory.
    pub fn shard_path(&self, k: usize) -> PathBuf {
        self.dir.join(&self.shards[k].file)
    }

    /// 64-bit fingerprint over the run shape, layout, and shard digests.
    /// Two manifests fingerprint equal iff they describe the same partition
    /// of the same data. Announced in the v2 `Setup` frame (0 = unsharded).
    pub fn fingerprint(&self) -> u64 {
        let mut s = FNV_OFFSET;
        s = fnv1a64_update(s, &(self.n as u64).to_le_bytes());
        s = fnv1a64_update(s, &(self.d as u64).to_le_bytes());
        s = fnv1a64_update(s, &[crate::net::wire::metric_code(self.metric)]);
        s = fnv1a64_update(s, &(self.shards.len() as u64).to_le_bytes());
        for e in &self.shards {
            s = fnv1a64_update(s, &e.part.to_le_bytes());
            s = fnv1a64_update(s, &(e.ids.len() as u64).to_le_bytes());
            for &g in &e.ids {
                s = fnv1a64_update(s, &g.to_le_bytes());
            }
            s = fnv1a64_update(s, &e.digest.to_le_bytes());
        }
        // reserve 0 as the "unsharded" sentinel
        s.max(1)
    }

    /// Structural checks: every id appears exactly once across shards,
    /// subsets are ascending and non-empty, counts match `n`.
    pub fn validate(&self) -> Result<()> {
        if self.shards.is_empty() {
            bail!("manifest has no shards");
        }
        if self.n == 0 || self.d == 0 {
            bail!("manifest n and d must be positive");
        }
        let mut seen = vec![false; self.n];
        for (k, e) in self.shards.iter().enumerate() {
            if e.part as usize != k {
                bail!("shard entries out of order: slot {k} holds part {}", e.part);
            }
            if e.ids.is_empty() {
                bail!("shard {k} is empty");
            }
            if !e.ids.windows(2).all(|w| w[0] < w[1]) {
                bail!("shard {k}: ids not strictly ascending");
            }
            for &g in &e.ids {
                let g = g as usize;
                if g >= self.n {
                    bail!("shard {k}: id {g} outside n = {}", self.n);
                }
                if seen[g] {
                    bail!("id {g} appears in more than one shard");
                }
                seen[g] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            bail!("id {missing} is in no shard (layout does not cover 0..n)");
        }
        Ok(())
    }

    /// Serialize to manifest TOML-lite text.
    pub fn to_toml(&self) -> String {
        let mut s = String::new();
        s.push_str("# demst shard manifest — written by `demst partition`\n");
        s.push_str(&format!("version = {MANIFEST_VERSION}\n"));
        s.push_str(&format!("name = \"{}\"\n", self.name));
        s.push_str(&format!("n = {}\n", self.n));
        s.push_str(&format!("d = {}\n", self.d));
        s.push_str(&format!("parts = {}\n", self.shards.len()));
        s.push_str(&format!("metric = \"{}\"\n", self.metric.name()));
        s.push_str(&format!("strategy = \"{}\"\n", self.strategy.name()));
        // quoted: seeds are u64 and TOML-lite integers are i64 — a seed
        // >= 2^63 written bare would not parse back
        s.push_str(&format!("seed = \"{}\"\n", self.seed));
        s.push_str(&format!("fingerprint = \"{}\"\n", digest_hex(self.fingerprint())));
        for e in &self.shards {
            s.push_str(&format!("\n[shard{}]\n", e.part));
            s.push_str(&format!("file = \"{}\"\n", e.file));
            s.push_str(&format!("rows = {}\n", e.ids.len()));
            s.push_str(&format!("ids = \"{}\"\n", encode_id_ranges(&e.ids)));
            s.push_str(&format!("digest = \"{}\"\n", digest_hex(e.digest)));
        }
        s
    }

    /// Write the manifest into `dir` as `<name>.manifest.toml`; returns the
    /// written path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("{}.manifest.toml", self.name));
        std::fs::write(&path, self.to_toml())
            .with_context(|| format!("writing manifest {}", path.display()))?;
        Ok(path)
    }

    /// Load and validate a manifest file.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading shard manifest {}", path.display()))?;
        let mut m = Self::from_toml(&text)
            .with_context(|| format!("parsing shard manifest {}", path.display()))?;
        m.dir = path.parent().unwrap_or(Path::new(".")).to_path_buf();
        Ok(m)
    }

    /// Parse manifest TOML-lite text (no directory attached).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let root = doc.get("").ok_or_else(|| anyhow!("empty manifest"))?;
        let get = |key: &str| root.get(key).ok_or_else(|| anyhow!("manifest missing key {key:?}"));
        let get_usize = |key: &str| -> Result<usize> {
            let v = get(key)?.as_int().ok_or_else(|| anyhow!("manifest key {key:?} must be an integer"))?;
            usize::try_from(v).map_err(|_| anyhow!("manifest key {key:?} must be non-negative"))
        };
        let get_str = |key: &str| -> Result<&str> {
            get(key)?.as_str().ok_or_else(|| anyhow!("manifest key {key:?} must be a string"))
        };
        let version = get("version")?.as_int().unwrap_or(-1);
        if version != MANIFEST_VERSION {
            bail!("unsupported manifest version {version} (this build reads v{MANIFEST_VERSION})");
        }
        let parts = get_usize("parts")?;
        let metric = MetricKind::parse(get_str("metric")?)
            .ok_or_else(|| anyhow!("unknown manifest metric"))?;
        let strategy = PartitionStrategy::parse(get_str("strategy")?)
            .ok_or_else(|| anyhow!("unknown manifest strategy"))?;
        let mut shards = Vec::with_capacity(parts);
        for k in 0..parts {
            let sec = doc
                .get(&format!("shard{k}"))
                .ok_or_else(|| anyhow!("manifest missing [shard{k}] section"))?;
            let sget = |key: &str| {
                sec.get(key).ok_or_else(|| anyhow!("[shard{k}] missing key {key:?}"))
            };
            let file = sget("file")?
                .as_str()
                .ok_or_else(|| anyhow!("[shard{k}] file must be a string"))?
                .to_string();
            let rows = sget("rows")?
                .as_int()
                .ok_or_else(|| anyhow!("[shard{k}] rows must be an integer"))?;
            let ids = decode_id_ranges(
                sget("ids")?.as_str().ok_or_else(|| anyhow!("[shard{k}] ids must be a string"))?,
            )
            .with_context(|| format!("[shard{k}] ids"))?;
            if ids.len() as i64 != rows {
                bail!("[shard{k}] rows = {rows} but ids decode to {} entries", ids.len());
            }
            let digest = sget("digest")?
                .as_str()
                .and_then(parse_digest_hex)
                .ok_or_else(|| anyhow!("[shard{k}] digest must be a hex string"))?;
            shards.push(ShardEntry { part: k as u32, file, ids, digest });
        }
        let seed = match get("seed")? {
            TomlValue::Int(i) if *i >= 0 => *i as u64,
            TomlValue::Str(s) => s
                .trim()
                .parse::<u64>()
                .map_err(|_| anyhow!("manifest seed {s:?} is not a u64"))?,
            _ => bail!("manifest key \"seed\" must be a non-negative integer (possibly quoted)"),
        };
        let m = Self {
            name: get_str("name")?.to_string(),
            n: get_usize("n")?,
            d: get_usize("d")?,
            metric,
            strategy,
            seed,
            shards,
            dir: PathBuf::new(),
        };
        m.validate()?;
        if let Some(TomlValue::Str(fp)) = root.get("fingerprint") {
            let recorded = parse_digest_hex(fp)
                .ok_or_else(|| anyhow!("manifest fingerprint is not a hex digest"))?;
            let actual = m.fingerprint();
            if recorded != actual {
                bail!(
                    "manifest fingerprint mismatch: recorded {recorded:#018x}, layout hashes to {actual:#018x} (hand-edited manifest?)"
                );
            }
        }
        Ok(m)
    }
}

/// Encode an ascending id list as compact ranges: `0-4,9,12-20`.
pub fn encode_id_ranges(ids: &[u32]) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < ids.len() {
        let start = ids[i];
        let mut end = start;
        while i + 1 < ids.len() && ids[i + 1] == end + 1 {
            i += 1;
            end = ids[i];
        }
        if !out.is_empty() {
            out.push(',');
        }
        if end == start {
            out.push_str(&start.to_string());
        } else {
            out.push_str(&format!("{start}-{end}"));
        }
        i += 1;
    }
    out
}

/// Decode a `0-4,9,12-20` range string back to the ascending id list.
/// Rejects descending ranges, overlaps, and garbage.
pub fn decode_id_ranges(s: &str) -> Result<Vec<u32>> {
    let mut ids = Vec::new();
    for piece in s.split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        let (lo, hi) = match piece.split_once('-') {
            Some((a, b)) => (
                a.trim().parse::<u32>().with_context(|| format!("bad range start {a:?}"))?,
                b.trim().parse::<u32>().with_context(|| format!("bad range end {b:?}"))?,
            ),
            None => {
                let v = piece.parse::<u32>().with_context(|| format!("bad id {piece:?}"))?;
                (v, v)
            }
        };
        if hi < lo {
            bail!("descending range {piece:?}");
        }
        if let Some(&last) = ids.last() {
            if lo <= last {
                bail!("ranges must be ascending and non-overlapping (at {piece:?})");
            }
        }
        ids.extend(lo..=hi);
    }
    if ids.is_empty() {
        bail!("empty id list");
    }
    Ok(ids)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Manifest {
        Manifest {
            name: "t".into(),
            n: 10,
            d: 3,
            metric: MetricKind::Euclid,
            strategy: PartitionStrategy::Block,
            seed: 42,
            shards: vec![
                ShardEntry { part: 0, file: "t.shard0.bin".into(), ids: vec![0, 1, 2, 3, 4], digest: 7 },
                ShardEntry { part: 1, file: "t.shard1.bin".into(), ids: vec![5, 7, 9], digest: 8 },
                ShardEntry { part: 2, file: "t.shard2.bin".into(), ids: vec![6, 8], digest: 9 },
            ],
            dir: PathBuf::new(),
        }
    }

    #[test]
    fn id_ranges_roundtrip() {
        for ids in [
            vec![0u32, 1, 2, 3],
            vec![5],
            vec![0, 2, 4, 6],
            vec![1, 2, 3, 7, 9, 10, 11, 40],
        ] {
            let enc = encode_id_ranges(&ids);
            assert_eq!(decode_id_ranges(&enc).unwrap(), ids, "{enc}");
        }
        assert_eq!(encode_id_ranges(&[0, 1, 2, 3]), "0-3");
        assert_eq!(encode_id_ranges(&[1, 3, 4, 5, 9]), "1,3-5,9");
        assert!(decode_id_ranges("5-2").is_err());
        assert!(decode_id_ranges("1,1").is_err());
        assert!(decode_id_ranges("x").is_err());
        assert!(decode_id_ranges("").is_err());
    }

    #[test]
    fn toml_roundtrip_preserves_everything() {
        let m = sample_manifest();
        let text = m.to_toml();
        let back = Manifest::from_toml(&text).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.fingerprint(), m.fingerprint());
        assert_eq!(back.layout(), vec![vec![0, 1, 2, 3, 4], vec![5, 7, 9], vec![6, 8]]);
    }

    #[test]
    fn seed_roundtrips_past_i64_range() {
        // u64 seeds beyond i64::MAX must survive the TOML-lite round trip
        // (written quoted); bare non-negative integers stay accepted.
        let mut m = sample_manifest();
        m.seed = u64::MAX - 7;
        let back = Manifest::from_toml(&m.to_toml()).unwrap();
        assert_eq!(back.seed, u64::MAX - 7);
        let bare = m.to_toml().replace(&format!("seed = \"{}\"", m.seed), "seed = 42");
        assert_eq!(Manifest::from_toml(&bare).unwrap().seed, 42);
        let bad = m.to_toml().replace(&format!("seed = \"{}\"", m.seed), "seed = \"nope\"");
        assert!(Manifest::from_toml(&bad).is_err());
    }

    #[test]
    fn validation_catches_bad_layouts() {
        let mut m = sample_manifest();
        m.shards[1].ids = vec![5, 7]; // id 9 now missing
        assert!(m.validate().unwrap_err().to_string().contains("no shard"));
        let mut m = sample_manifest();
        m.shards[2].ids = vec![6, 8, 9]; // 9 duplicated
        assert!(m.validate().unwrap_err().to_string().contains("more than one"));
        let mut m = sample_manifest();
        m.shards[0].ids = vec![0, 0, 1, 2, 3];
        assert!(m.validate().is_err());
    }

    #[test]
    fn fingerprint_tracks_layout_and_digests() {
        let a = sample_manifest();
        let mut b = sample_manifest();
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.shards[1].digest = 1234;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = sample_manifest();
        c.shards[1].ids = vec![5, 7, 8];
        c.shards[2].ids = vec![6, 9];
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_ne!(a.fingerprint(), 0, "0 is reserved for unsharded");
    }

    #[test]
    fn tampered_fingerprint_rejected() {
        let m = sample_manifest();
        let text = m.to_toml().replace(&digest_hex(m.fingerprint()), &digest_hex(12345));
        let err = Manifest::from_toml(&text).unwrap_err().to_string();
        assert!(err.contains("fingerprint mismatch"), "{err}");
    }

    #[test]
    fn write_and_load() {
        let dir = std::env::temp_dir().join("demst_manifest_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample_manifest();
        let path = m.write(&dir).unwrap();
        let back = Manifest::load(&path).unwrap();
        assert_eq!(back.dir, dir);
        assert_eq!(back.shards, m.shards);
        assert_eq!(back.shard_path(1), dir.join("t.shard1.bin"));
    }
}
