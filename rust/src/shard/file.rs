//! The binary shard file: one partition subset's global-id map and vector
//! rows, checksummed, loadable by a worker without touching the leader.
//!
//! ## Layout (little-endian)
//!
//! ```text
//! [0..4]   magic "DMSH"
//! [4..6]   format version (u16, = 1)
//! [6..8]   reserved (0)
//! [8..12]  part: u32        (partition subset index)
//! [12..16] rows: u32        (|S_k|)
//! [16..20] d: u32           (dimensions)
//! [20..24] reserved (0)
//! [24..]   ids:  rows × u32 (ascending global ids)
//!          data: rows × d × f32 (row-major vectors)
//! [-8..]   fnv1a64 over every preceding byte
//! ```
//!
//! The trailing digest doubles as the manifest's per-shard digest, so a
//! worker can verify both "this file is intact" and "this file is the one
//! the manifest describes" with a single pass.

use super::digest::fnv1a64;
use crate::data::Dataset;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MAGIC: &[u8; 4] = b"DMSH";
const FORMAT_VERSION: u16 = 1;
const HEADER_BYTES: usize = 24;

/// One shard loaded from disk: the subset index, its ascending global-id
/// map, and the gathered rows.
#[derive(Clone, Debug, PartialEq)]
pub struct Shard {
    pub part: u32,
    pub ids: Vec<u32>,
    pub points: Dataset,
}

impl Shard {
    /// Bytes of vector payload this shard keeps worker-local (id map +
    /// rows) — the quantity [`RunMetrics::shard_local_bytes`] aggregates.
    ///
    /// [`RunMetrics::shard_local_bytes`]: crate::coordinator::RunMetrics
    pub fn local_payload_bytes(&self) -> u64 {
        crate::net::wire::vectors_payload_bytes(self.ids.len(), self.points.d)
    }
}

/// Serialize one shard to its binary form (including the digest trailer).
/// Returns `(bytes, digest)`.
pub fn encode_shard(part: u32, ids: &[u32], points: &Dataset) -> Result<(Vec<u8>, u64)> {
    if ids.len() != points.n {
        bail!("shard {part}: id map length {} != rows {}", ids.len(), points.n);
    }
    let mut buf =
        Vec::with_capacity(HEADER_BYTES + ids.len() * 4 + points.n * points.d * 4 + 8);
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
    buf.extend_from_slice(&[0u8; 2]);
    buf.extend_from_slice(&part.to_le_bytes());
    buf.extend_from_slice(&u32::try_from(ids.len()).context("shard rows exceed u32")?.to_le_bytes());
    buf.extend_from_slice(&u32::try_from(points.d).context("shard d exceeds u32")?.to_le_bytes());
    buf.extend_from_slice(&[0u8; 4]);
    for &g in ids {
        buf.extend_from_slice(&g.to_le_bytes());
    }
    for &v in points.as_slice() {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    let digest = fnv1a64(&buf);
    buf.extend_from_slice(&digest.to_le_bytes());
    Ok((buf, digest))
}

/// Write one shard file; returns its content digest.
pub fn write_shard(path: &Path, part: u32, ids: &[u32], points: &Dataset) -> Result<u64> {
    let (buf, digest) = encode_shard(part, ids, points)?;
    std::fs::write(path, &buf).with_context(|| format!("writing shard {}", path.display()))?;
    Ok(digest)
}

/// Decode a shard from its binary form, verifying the checksum.
pub fn decode_shard(buf: &[u8]) -> Result<Shard> {
    if buf.len() < HEADER_BYTES + 8 {
        bail!("shard file truncated: {} bytes", buf.len());
    }
    if &buf[0..4] != MAGIC {
        bail!("not a demst shard file (bad magic)");
    }
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    if version != FORMAT_VERSION {
        bail!("unsupported shard format version {version} (this build reads v{FORMAT_VERSION})");
    }
    let body = &buf[..buf.len() - 8];
    let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    let computed = fnv1a64(body);
    if stored != computed {
        bail!(
            "shard checksum mismatch: file says {stored:#018x}, content hashes to {computed:#018x} (corrupt or truncated copy?)"
        );
    }
    let part = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    let rows = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
    let d = u32::from_le_bytes(buf[16..20].try_into().unwrap()) as usize;
    let need = HEADER_BYTES + rows * 4 + rows * d * 4;
    if body.len() != need {
        bail!("shard payload length {} != header-declared {need}", body.len());
    }
    let mut at = HEADER_BYTES;
    let ids: Vec<u32> = buf[at..at + rows * 4]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    at += rows * 4;
    let data: Vec<f32> = buf[at..at + rows * d * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    if !ids.windows(2).all(|w| w[0] < w[1]) {
        bail!("shard {part}: global ids are not strictly ascending");
    }
    Ok(Shard { part, ids, points: Dataset::new(rows, d, data) })
}

/// Read and verify one shard file.
pub fn read_shard(path: &Path) -> Result<Shard> {
    let buf = std::fs::read(path).with_context(|| format!("reading shard {}", path.display()))?;
    decode_shard(&buf).with_context(|| format!("decoding shard {}", path.display()))
}

/// Digest of an already-encoded shard file's contents (what `write_shard`
/// recorded), recomputed from the stored trailer for cross-checks.
pub fn shard_digest(buf: &[u8]) -> Result<u64> {
    if buf.len() < 8 {
        bail!("shard file truncated");
    }
    Ok(u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("demst_shard_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample(seed: u64, rows: usize, d: usize) -> (Vec<u32>, Dataset) {
        let mut rng = Pcg64::seeded(seed);
        let ids: Vec<u32> = (0..rows as u32).map(|i| i * 3 + 1).collect();
        let data: Vec<f32> = (0..rows * d).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
        (ids, Dataset::new(rows, d, data))
    }

    #[test]
    fn roundtrip_bit_identical() {
        let (ids, points) = sample(1, 13, 5);
        let p = tmp("roundtrip.bin");
        let digest = write_shard(&p, 7, &ids, &points).unwrap();
        let shard = read_shard(&p).unwrap();
        assert_eq!(shard.part, 7);
        assert_eq!(shard.ids, ids);
        assert_eq!(shard.points, points);
        assert_eq!(shard_digest(&std::fs::read(&p).unwrap()).unwrap(), digest);
        assert_eq!(shard.local_payload_bytes(), 13 * 4 + 13 * 5 * 4);
    }

    #[test]
    fn corruption_detected() {
        let (ids, points) = sample(2, 9, 3);
        let p = tmp("corrupt.bin");
        write_shard(&p, 0, &ids, &points).unwrap();
        let mut buf = std::fs::read(&p).unwrap();
        let at = buf.len() / 2;
        buf[at] ^= 0x40;
        let err = decode_shard(&buf).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        // truncation is also caught
        assert!(decode_shard(&buf[..buf.len() - 3]).is_err());
    }

    #[test]
    fn rejects_foreign_and_malformed() {
        assert!(decode_shard(b"not a shard").is_err());
        let (ids, points) = sample(3, 4, 2);
        let (mut buf, _) = encode_shard(1, &ids, &points).unwrap();
        buf[4] = 99; // version check precedes the digest check
        let err = decode_shard(&buf).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn mismatched_id_map_rejected_at_encode() {
        let (_, points) = sample(4, 4, 2);
        assert!(encode_shard(0, &[1, 2], &points).is_err());
    }
}
