//! FNV-1a 64-bit digests for shard payload integrity.
//!
//! Not cryptographic — the threat model is bit rot, truncated copies, and
//! mismatched manifest/shard pairs across hosts, where a fast incremental
//! 64-bit checksum is the right tool. The same function fingerprints whole
//! manifests so the leader's `Setup` frame can refuse a worker that loaded
//! shards cut from a different partition run.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64: fold `bytes` into `state` and return the new
/// state. Start from [`FNV_OFFSET`].
#[inline]
pub fn fnv1a64_update(mut state: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        state ^= b as u64;
        state = state.wrapping_mul(FNV_PRIME);
    }
    state
}

/// One-shot FNV-1a 64 of `bytes`.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_update(FNV_OFFSET, bytes)
}

/// Render a digest the way manifests store it (`0x`-prefixed hex).
pub fn digest_hex(d: u64) -> String {
    format!("0x{d:016x}")
}

/// Parse a manifest digest string (with or without the `0x` prefix).
pub fn parse_digest_hex(s: &str) -> Option<u64> {
    let s = s.trim();
    let hex = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")).unwrap_or(s);
    u64::from_str_radix(hex, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_equals_oneshot() {
        let whole = fnv1a64(b"hello world");
        let mut s = FNV_OFFSET;
        s = fnv1a64_update(s, b"hello ");
        s = fnv1a64_update(s, b"world");
        assert_eq!(whole, s);
    }

    #[test]
    fn hex_roundtrip() {
        for d in [0u64, 1, u64::MAX, 0xdead_beef_0123_4567] {
            assert_eq!(parse_digest_hex(&digest_hex(d)), Some(d));
        }
        assert_eq!(parse_digest_hex("cbf29ce484222325"), Some(FNV_OFFSET));
        assert_eq!(parse_digest_hex("zz"), None);
    }
}
