//! Sharded dataset residency: the subsystem that moves vector payload off
//! the leader and onto the worker hosts.
//!
//! `demst partition` splits a dataset into per-subset **shard files**
//! ([`file`]: binary, checksummed) described by a **manifest**
//! ([`manifest`]: TOML-lite — run shape, partition layout as id ranges,
//! per-shard digests, and a 64-bit fingerprint). Each `demst worker
//! --shard <manifest> --shard-ids ...` process loads its shards from local
//! disk at startup ([`load_worker_shards`]) and advertises the resident
//! subset ids during the versioned handshake; the leader plans the run from the
//! manifest alone ([`Manifest::layout`]), treats advertised subsets as
//! already-held in its resident-set `Shipment` model, and restricts
//! scheduling to workers that hold both subsets of a pair job — so subset
//! vectors never pass through the leader (`RunMetrics::leader_ingest_bytes
//! == 0` on a sharded run, `shard_local_bytes` accounts what the fleet
//! loaded locally instead).
//!
//! Because every pair job `(i, j)` needs both subsets co-resident
//! somewhere, a shard assignment must cover all `|P|(|P|-1)/2` pairs;
//! [`suggest_assignment`] produces a covering layout (the classic
//! group-pair scheme from the MPC literature) that `demst partition`
//! prints as ready-to-paste `--shard-ids` flags.

pub mod digest;
pub mod file;
pub mod manifest;

pub use digest::{digest_hex, fnv1a64, parse_digest_hex};
pub use file::{read_shard, write_shard, Shard};
pub use manifest::{decode_id_ranges, encode_id_ranges, Manifest, ShardEntry};

use crate::data::Dataset;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Split `ds` with the given partitioner and write one shard file per
/// subset plus the manifest into `dir`. Returns the manifest (with `dir`
/// attached) and the manifest file path.
pub fn write_dataset_shards(
    dir: &Path,
    name: &str,
    ds: &Dataset,
    parts: usize,
    strategy: crate::decomp::PartitionStrategy,
    seed: u64,
    metric: crate::geometry::MetricKind,
) -> Result<(Manifest, std::path::PathBuf)> {
    if name.is_empty() || name.contains(['/', '\\', '"']) {
        bail!("shard set name {name:?} must be a plain file stem");
    }
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating shard directory {}", dir.display()))?;
    let layout = crate::decomp::partition_indices(ds, parts, strategy, seed);
    let mut shards = Vec::with_capacity(parts);
    for (k, ids) in layout.iter().enumerate() {
        let file_name = format!("{name}.shard{k}.bin");
        let digest =
            file::write_shard(&dir.join(&file_name), k as u32, ids, &ds.gather(ids))?;
        shards.push(ShardEntry { part: k as u32, file: file_name, ids: ids.clone(), digest });
    }
    let m = Manifest {
        name: name.to_string(),
        n: ds.n,
        d: ds.d,
        metric,
        strategy,
        seed,
        shards,
        dir: dir.to_path_buf(),
    };
    m.validate()?;
    let path = m.write(dir)?;
    Ok((m, path))
}

/// Load (and digest-verify) the shards a worker was asked to hold. `ids`
/// must name valid subsets of the manifest; each shard file must match the
/// manifest's recorded digest, row count, ids, and dimensions.
pub fn load_worker_shards(m: &Manifest, ids: &[u32]) -> Result<Vec<Shard>> {
    let mut sorted: Vec<u32> = ids.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let mut out = Vec::with_capacity(sorted.len());
    for &k in &sorted {
        let entry = m
            .shards
            .get(k as usize)
            .ok_or_else(|| anyhow::anyhow!("--shard-ids names subset {k} but the manifest has {} shards", m.parts()))?;
        let path = m.shard_path(k as usize);
        let shard = file::read_shard(&path)?;
        if shard.part != k {
            bail!("{}: file says part {}, manifest slot is {k}", path.display(), shard.part);
        }
        let raw = std::fs::read(&path)?;
        let actual = file::shard_digest(&raw)?;
        if actual != entry.digest {
            bail!(
                "{}: digest {actual:#018x} does not match the manifest's {:#018x} — stale or foreign shard file",
                path.display(),
                entry.digest
            );
        }
        if shard.ids != entry.ids || shard.points.d != m.d {
            bail!("{}: shard contents disagree with the manifest layout", path.display());
        }
        out.push(shard);
    }
    Ok(out)
}

/// Suggest a pair-covering shard assignment for `workers` hosts: subsets
/// are split round-robin into `g` groups (the largest `g` with
/// `g(g+1)/2 <= workers`), worker `w` holds the union of group pair `w`
/// (enumerating unordered pairs `(a, b)`, `a <= b`); extra workers repeat
/// pairs round-robin. Every subset pair lands co-resident on at least one
/// worker, so any pair job is schedulable — the structure the MPC
/// literature uses to co-locate all pairwise blocks without replication to
/// every host.
pub fn suggest_assignment(parts: usize, workers: usize) -> Vec<Vec<u32>> {
    assert!(parts >= 1 && workers >= 1);
    let mut g = 1usize;
    while (g + 1) * (g + 2) / 2 <= workers {
        g += 1;
    }
    let g = g.min(parts); // no point in more groups than subsets
    let mut groups: Vec<Vec<u32>> = vec![Vec::new(); g];
    for k in 0..parts {
        groups[k % g].push(k as u32);
    }
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    for a in 0..g {
        for b in a..g {
            pairs.push((a, b));
        }
    }
    (0..workers)
        .map(|w| {
            let (a, b) = pairs[w % pairs.len()];
            let mut ids = groups[a].clone();
            if b != a {
                ids.extend_from_slice(&groups[b]);
            }
            ids.sort_unstable();
            ids
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::PartitionStrategy;
    use crate::geometry::MetricKind;
    use crate::util::prng::Pcg64;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("demst_shard_mod_tests").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_ds(seed: u64, n: usize, d: usize) -> Dataset {
        let mut rng = Pcg64::seeded(seed);
        Dataset::new(n, d, (0..n * d).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
    }

    #[test]
    fn partition_write_load_roundtrip() {
        let dir = tmpdir("roundtrip");
        let ds = sample_ds(11, 37, 4);
        let (m, path) = write_dataset_shards(
            &dir,
            "t",
            &ds,
            4,
            PartitionStrategy::RandomShuffle,
            9,
            MetricKind::Cosine,
        )
        .unwrap();
        let loaded = Manifest::load(&path).unwrap();
        assert_eq!(loaded.fingerprint(), m.fingerprint());
        assert_eq!(loaded.layout(), crate::decomp::partition_indices(
            &ds,
            4,
            PartitionStrategy::RandomShuffle,
            9
        ));
        // every shard loads, verifies, and is bit-identical to the gather
        let shards = load_worker_shards(&loaded, &[0, 1, 2, 3]).unwrap();
        for s in &shards {
            assert_eq!(s.points, ds.gather(&s.ids));
        }
        // a worker loading a subset of the shards gets exactly those
        let some = load_worker_shards(&loaded, &[2, 0, 2]).unwrap();
        assert_eq!(some.iter().map(|s| s.part).collect::<Vec<_>>(), vec![0, 2]);
    }

    #[test]
    fn stale_shard_file_rejected() {
        let dir = tmpdir("stale");
        let ds = sample_ds(12, 20, 3);
        let (_, path) =
            write_dataset_shards(&dir, "t", &ds, 2, PartitionStrategy::Block, 0, MetricKind::SqEuclid)
                .unwrap();
        // overwrite shard 1 with a shard cut from different data
        let other = sample_ds(99, 20, 3);
        let ids: Vec<u32> = (10..20).collect();
        file::write_shard(&dir.join("t.shard1.bin"), 1, &ids, &other.gather(&ids)).unwrap();
        let m = Manifest::load(&path).unwrap();
        let err = load_worker_shards(&m, &[1]).unwrap_err().to_string();
        assert!(err.contains("does not match the manifest"), "{err}");
    }

    #[test]
    fn unknown_shard_id_rejected() {
        let dir = tmpdir("unknown");
        let ds = sample_ds(13, 12, 2);
        let (m, _) =
            write_dataset_shards(&dir, "t", &ds, 3, PartitionStrategy::Block, 0, MetricKind::SqEuclid)
                .unwrap();
        assert!(load_worker_shards(&m, &[7]).is_err());
    }

    #[test]
    fn suggested_assignment_covers_every_pair() {
        for parts in [2usize, 4, 5, 9] {
            for workers in [1usize, 2, 3, 6, 10] {
                let assign = suggest_assignment(parts, workers);
                assert_eq!(assign.len(), workers);
                for i in 0..parts as u32 {
                    for j in (i + 1)..parts as u32 {
                        assert!(
                            assign.iter().any(|a| a.contains(&i) && a.contains(&j)),
                            "parts={parts} workers={workers}: pair ({i},{j}) not co-resident"
                        );
                    }
                }
            }
        }
        // single worker: must hold everything
        assert_eq!(suggest_assignment(5, 1)[0], vec![0, 1, 2, 3, 4]);
    }
}
