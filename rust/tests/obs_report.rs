//! Observability reconciliation: the span timeline, the Chrome-trace
//! export, and the run report must agree **exactly** with `RunMetrics` —
//! the telemetry is an account of the run, not an approximation of it.
//!
//! Two layers:
//! - an in-process traced run where every eval is attributable: span counts
//!   and per-span eval args must sum to the metric counters bit-exactly;
//! - the acceptance scenario: a *sharded tcp* run with a worker killed
//!   mid-run — the reassembled timeline must still contain a job span for
//!   every executed pair job (the dead worker's are synthesized at the
//!   leader from result receipt times) plus the failover instant, and the
//!   trace document must stay valid Chrome-trace JSON.

use demst::config::{KernelChoice, PairKernelChoice, RunConfig, TransportChoice};
use demst::coordinator::run_distributed;
use demst::data::Dataset;
use demst::geometry::MetricKind;
use demst::net::launch;
use demst::net::worker::CHAOS_EXIT_ENV;
use demst::obs::report::render_run_report;
use demst::obs::trace::render_chrome_trace;
use demst::obs::{Span, SpanKind};
use demst::util::prng::Pcg64;
use std::collections::HashSet;
use std::net::TcpListener;
use std::path::PathBuf;

fn float_dataset(seed: u64, n: usize, d: usize) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
    Dataset::new(n, d, data)
}

fn spans_of(spans: &[Span], kind: SpanKind) -> Vec<&Span> {
    spans.iter().filter(|s| s.kind() == Some(kind)).collect()
}

/// In-process traced run: every span arg is an exact per-thread eval delta
/// (bipartite pair solvers own their counters; Prim over m points evaluates
/// exactly C(m,2)), so the timeline must reconcile with the counters to the
/// last eval: Σ job args == pair_evals, Σ local_mst args == local_mst_evals,
/// and their sum is dist_evals.
#[test]
fn traced_run_spans_reconcile_exactly_with_metrics() {
    let ds = float_dataset(9500, 140, 8);
    let parts = 5usize; // 10 pair jobs
    let mut cfg = RunConfig {
        parts,
        workers: 3,
        kernel: KernelChoice::PrimDense,
        pair_kernel: PairKernelChoice::BipartiteMerge,
        ..Default::default()
    };
    cfg.obs.trace = true;
    let out = run_distributed(&ds, &cfg).unwrap();
    let m = &out.metrics;
    assert!(!m.spans.is_empty(), "tracing on must record spans");

    let jobs = spans_of(&m.spans, SpanKind::Job);
    assert_eq!(jobs.len(), m.jobs as usize, "one job span per executed pair job");
    let job_ids: HashSet<u32> = jobs.iter().map(|s| s.id).collect();
    assert_eq!(job_ids.len(), jobs.len(), "job span ids are unique");
    let job_evals: u64 = jobs.iter().map(|s| s.arg).sum();
    assert_eq!(job_evals, m.pair_evals, "job span args sum to pair_evals");

    let locals = spans_of(&m.spans, SpanKind::LocalMst);
    assert_eq!(locals.len(), parts, "one local_mst span per subset");
    let local_evals: u64 = locals.iter().map(|s| s.arg).sum();
    assert_eq!(local_evals, m.local_mst_evals, "local_mst span args sum to local_mst_evals");
    assert_eq!(job_evals + local_evals, m.dist_evals, "spans account for every distance eval");

    for s in &m.spans {
        assert!(s.end_ns >= s.start_ns, "spans are forward in time: {s:?}");
    }
    // satellite (b): the printed per-worker roster derives from the final
    // fleet — with no admissions that is exactly the starting worker count
    assert_eq!(m.worker_busy.len(), cfg.workers, "per-worker roster covers the fleet");

    // --report-out document carries the same numbers verbatim
    let report = render_run_report(&cfg, m);
    for needle in [
        format!("\"jobs\": {}", m.jobs),
        format!("\"dist_evals\": {}", m.dist_evals),
        format!("\"pair_evals\": {}", m.pair_evals),
        format!("\"job_evals\": {}", m.pair_evals),
        format!("\"local_mst_evals\": {}", m.local_mst_evals),
        format!("\"total\": {}", m.spans.len()),
        format!("\"job\": {}", m.jobs),
        format!("\"local_mst\": {parts}"),
    ] {
        assert!(report.contains(&needle), "report lacks {needle}:\n{report}");
    }

    // --trace-out document: one duration event per span, one named track
    // per contributing worker
    let trace = render_chrome_trace(m);
    assert_eq!(
        trace.matches("\"name\": \"job\"").count(),
        jobs.len(),
        "one trace event per job span"
    );
    assert_eq!(
        trace.matches("\"name\": \"local_mst\"").count(),
        parts,
        "one trace event per local_mst span"
    );
    let tracks: HashSet<u16> = m.spans.iter().map(|s| s.worker).collect();
    assert_eq!(
        trace.matches("\"thread_name\"").count(),
        tracks.len(),
        "one named track per contributing thread"
    );
}

/// With tracing off (the default), the recorder must stay disarmed on the
/// job hot path and the run must carry zero spans.
#[test]
fn untraced_run_records_nothing() {
    let ds = float_dataset(9501, 80, 6);
    let cfg = RunConfig {
        parts: 4,
        workers: 2,
        kernel: KernelChoice::PrimDense,
        pair_kernel: PairKernelChoice::BipartiteMerge,
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg).unwrap();
    assert!(out.metrics.spans.is_empty(), "no tracing, no spans");
    assert!(!demst::obs::recording(), "recorder stays disarmed after an untraced run");
}

/// Acceptance scenario: a sharded tcp run with a worker killed mid-run.
/// The dead worker never ships its span buffer, yet the reassembled
/// timeline must contain a job span for **every** executed pair job (the
/// leader synthesizes the missing ones from its job-receipt log), plus the
/// failover instant for the death — and the exported trace stays a valid
/// Chrome-trace document with one event per job.
#[test]
fn sharded_tcp_run_with_killed_worker_covers_every_job_span() {
    let ds = float_dataset(9502, 150, 6);
    let parts = 6usize; // 15 pair jobs: plenty left when the chaos worker dies
    let dir = std::env::temp_dir().join("demst_obs_shards");
    std::fs::create_dir_all(&dir).unwrap();
    let (manifest, manifest_path): (demst::shard::Manifest, PathBuf) =
        demst::shard::write_dataset_shards(
            &dir,
            "obs_kill",
            &ds,
            parts,
            demst::decomp::PartitionStrategy::Block,
            0,
            MetricKind::SqEuclid,
        )
        .unwrap();

    let mut cfg = RunConfig {
        parts,
        workers: 2,
        kernel: KernelChoice::PrimDense,
        pair_kernel: PairKernelChoice::BipartiteMerge,
        strategy: demst::decomp::PartitionStrategy::Block,
        transport: TransportChoice::Tcp,
        listen: Some("127.0.0.1:0".into()),
        shard_manifest: Some(manifest_path.clone()),
        // inline tree shipping: replanned jobs must not depend on fetching
        // cached trees from the worker that just died
        peer_route: Some(false),
        ..Default::default()
    };
    cfg.obs.trace = true;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let manifest_arg = manifest_path.to_str().unwrap().to_string();
    // Both workers hold every shard, so the survivor can absorb any
    // reassigned job. Worker 1 is rigged to die after its second pair job.
    let mut healthy = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
        .args(["worker", "--connect", &addr, "--shard", &manifest_arg])
        .spawn()
        .unwrap();
    let mut chaotic = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
        .args(["worker", "--connect", &addr, "--shard", &manifest_arg])
        .env(CHAOS_EXIT_ENV, "2")
        .spawn()
        .unwrap();

    let run = launch::serve_sharded(&manifest, &cfg, &listener)
        .unwrap_or_else(|e| panic!("sharded kill run failed: {e:#}"));
    let m = &run.metrics;
    assert_eq!(m.worker_failures, 1, "the chaos worker must be seen to die");
    assert!(m.jobs_reassigned > 0, "its claimed jobs must fail over");
    assert_eq!(m.jobs, 15, "every job recorded exactly once");
    assert!(m.sharded);

    let job_ids: HashSet<u32> = m
        .spans
        .iter()
        .filter(|s| s.kind() == Some(SpanKind::Job))
        .map(|s| s.id)
        .collect();
    assert_eq!(
        job_ids.len(),
        m.jobs as usize,
        "a job span survives for every executed pair job, dead worker included"
    );
    assert!(
        m.spans.iter().any(|s| s.kind() == Some(SpanKind::Failover)),
        "the death must appear as a failover instant"
    );
    assert!(
        m.spans.iter().any(|s| s.kind() == Some(SpanKind::Handshake)),
        "the survivor's handshake span made it back"
    );

    let trace = render_chrome_trace(m);
    assert!(trace.starts_with('{') && trace.trim_end().ends_with('}'));
    assert!(trace.contains("\"displayTimeUnit\""));
    assert!(trace.contains("\"name\": \"failover\""), "failover instant exported");
    assert_eq!(
        trace.matches("\"name\": \"job\"").count(),
        m.jobs as usize,
        "trace carries one job event per executed pair job"
    );

    let report = render_run_report(&cfg, m);
    assert!(report.contains("\"worker_failures\": 1"), "{report}");
    assert!(report.contains(&format!("\"jobs\": {}", m.jobs)), "{report}");

    assert!(healthy.wait().unwrap().success(), "survivor must exit 0");
    assert_eq!(chaotic.wait().unwrap().code(), Some(113), "chaos exit code");
}
