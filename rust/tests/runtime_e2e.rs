//! End-to-end tests of the PJRT runtime path: HLO artifacts → compile →
//! execute → exact agreement with the pure-Rust providers.
//!
//! These tests are skipped (with a loud message) when `artifacts/` has not
//! been built yet — run `make artifacts` (or `make artifacts-quick`) first.
//! The whole file only compiles with `--features backend-xla`; the default
//! build has no PJRT runtime.

#![cfg(feature = "backend-xla")]

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{gaussian_blobs, BlobSpec};
use demst::data::Dataset;
use demst::dense::step::{CheapestEdgeStep, NaiveStep};
use demst::dense::{BoruvkaDense, DenseMst, PrimDense};
use demst::geometry::MetricKind;
use demst::graph::components::is_spanning_tree;
use demst::mst::normalize_tree;
use demst::runtime::{Engine, XlaPairwise, XlaStep};
use demst::util::prng::Pcg64;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if Engine::artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} — run `make artifacts` first", dir.display());
        None
    }
}

/// Integer coordinates: matmul-form distances are exact, so XLA and Rust
/// paths must agree bit-for-bit (including tie-breaks).
fn int_dataset(seed: u64, n: usize, d: usize) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(25) as f32 - 12.0).collect();
    Dataset::new(n, d, data)
}

#[test]
fn xla_step_matches_naive_exact_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let step = XlaStep::new(engine);
    let (n, d) = (64, 8); // exact bucket, no padding
    let ds = int_dataset(1, n, d);
    let comps: Vec<i32> = (0..n as i32).map(|i| i % 5).collect();
    let (xd, xi) = step.step(ds.as_slice(), n, d, &comps);
    let (rd, ri) = NaiveStep.step(ds.as_slice(), n, d, &comps);
    assert_eq!(xi, ri, "indices identical (tie-break contract)");
    assert_eq!(xd, rd, "integer coords: distances bit-exact");
}

#[test]
fn xla_step_pads_rows_and_dims() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let step = XlaStep::new(engine);
    // n=50 pads to 64 rows; d=5 pads to 8 dims (quick bucket set)
    let (n, d) = (50, 5);
    let ds = int_dataset(2, n, d);
    let comps: Vec<i32> = (0..n as i32).map(|i| i % 3).collect();
    let (xd, xi) = step.step(ds.as_slice(), n, d, &comps);
    let (rd, ri) = NaiveStep.step(ds.as_slice(), n, d, &comps);
    assert_eq!(xi, ri);
    assert_eq!(xd, rd);
    assert_eq!(xd.len(), n, "outputs unpadded");
}

#[test]
fn xla_step_handles_padding_labels_inside_problem() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let step = XlaStep::new(engine);
    let (n, d) = (64, 8);
    let ds = int_dataset(3, n, d);
    let mut comps: Vec<i32> = (0..n as i32).map(|i| i % 4).collect();
    comps[7] = -1;
    comps[40] = -1;
    let (xd, xi) = step.step(ds.as_slice(), n, d, &comps);
    let (rd, ri) = NaiveStep.step(ds.as_slice(), n, d, &comps);
    assert_eq!(xi, ri);
    assert_eq!(xd, rd);
    assert_eq!(xi[7], -1);
    assert!(xd[7].is_infinite());
}

#[test]
fn boruvka_xla_matches_prim_dense() {
    let Some(dir) = artifacts_dir() else { return };
    for (seed, n, d) in [(10u64, 40usize, 6usize), (11, 64, 8), (12, 100, 16), (13, 128, 32)] {
        let ds = int_dataset(seed, n, d);
        let expect = PrimDense::sq_euclid().mst(&ds);
        let engine = Engine::load(&dir).unwrap();
        let kernel = BoruvkaDense::new(
            std::sync::Arc::new(XlaStep::new(engine)),
            MetricKind::SqEuclid,
        );
        let got = kernel.mst(&ds);
        assert!(is_spanning_tree(ds.n, &got), "n={n}");
        assert_eq!(normalize_tree(&expect), normalize_tree(&got), "seed={seed} n={n} d={d}");
    }
}

#[test]
fn xla_pairwise_matches_rust_blocked() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let pw = XlaPairwise::new(engine);
    let (n, d) = (60, 20); // pads to (64, 32)
    let ds = int_dataset(20, n, d);
    let got = pw.matrix(ds.as_slice(), n, d).unwrap();
    let want = demst::geometry::blocked::pairwise_self(ds.as_slice(), n, d);
    assert_eq!(got.len(), n * n);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() <= 1e-4 * (1.0 + w), "entry {i}: xla={g} rust={w}");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let step = XlaStep::new(engine);
    let ds = int_dataset(30, 64, 8);
    let comps: Vec<i32> = (0..64).map(|i| (i % 2) as i32).collect();
    let _ = step.step(ds.as_slice(), 64, 8, &comps);
    assert_eq!(step.engine().cached_executables(), 1);
    let _ = step.step(ds.as_slice(), 64, 8, &comps);
    assert_eq!(step.engine().cached_executables(), 1, "second call hits cache");
    // different bucket compiles a second executable
    let ds2 = int_dataset(31, 100, 8);
    let comps2: Vec<i32> = (0..100).map(|i| (i % 2) as i32).collect();
    let _ = step.step(ds2.as_slice(), 100, 8, &comps2);
    assert_eq!(step.engine().cached_executables(), 2);
}

#[test]
fn distributed_run_with_xla_kernel() {
    let Some(dir) = artifacts_dir() else { return };
    let spec = BlobSpec { n: 120, d: 8, k: 4, std: 0.3, spread: 6.0 };
    let raw = gaussian_blobs(&spec, Pcg64::seeded(40));
    // quantize for cross-path exactness
    let data: Vec<f32> = raw.as_slice().iter().map(|x| (x * 4.0).round() / 4.0).collect();
    let ds = Dataset::new(raw.n, raw.d, data);

    let mut cfg = RunConfig::default();
    cfg.parts = 4;
    cfg.workers = 2;
    cfg.artifacts_dir = dir;
    cfg.kernel = KernelChoice::BoruvkaXla;
    let xla_out = run_distributed(&ds, &cfg).unwrap();

    cfg.kernel = KernelChoice::PrimDense;
    let rust_out = run_distributed(&ds, &cfg).unwrap();

    assert_eq!(
        normalize_tree(&rust_out.mst),
        normalize_tree(&xla_out.mst),
        "three-layer stack reproduces the pure-Rust tree"
    );
}

#[test]
fn missing_bucket_reports_helpful_error() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::load(&dir).unwrap();
    let err = engine.bucket_for("cheapest_edge", 1 << 20, 8).unwrap_err().to_string();
    assert!(err.contains("no artifact bucket fits"), "{err}");
    assert!(err.contains("make artifacts"), "{err}");
}
