//! Cross-module integration tests: the paper's pipeline glued end-to-end
//! through the public API (no XLA required — see runtime_e2e.rs for that).

use demst::config::run_config::build_dataset;
use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::{embedding_like, gaussian_blobs_labeled, BlobSpec, EmbeddingSpec};
use demst::data::Dataset;
use demst::decomp::{decomposed_mst, DecompConfig, PartitionStrategy};
use demst::dense::{BoruvkaDense, DenseMst, PrimDense};
use demst::geometry::metric::PlainMetric;
use demst::geometry::MetricKind;
use demst::graph::components::is_spanning_tree;
use demst::mst::{kruskal, normalize_tree, prim_sparse, total_weight, boruvka_sparse};
use demst::slink::{mst_to_dendrogram, slink, slink_mst};
use demst::util::prng::Pcg64;

/// The one big invariant: every route to the MST yields the identical tree.
#[test]
fn all_roads_lead_to_the_same_mst() {
    // integer-ish coordinates so every arithmetic path is exact
    let mut rng = Pcg64::seeded(1000);
    let (n, d) = (150, 12);
    let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(41) as f32 / 2.0 - 10.0).collect();
    let ds = Dataset::new(n, d, data);
    let metric = PlainMetric(MetricKind::SqEuclid);

    // 1. dense Prim
    let t1 = PrimDense::sq_euclid().mst(&ds);
    // 2. dense Borůvka (blocked step)
    let t2 = BoruvkaDense::new_rust(MetricKind::SqEuclid).mst(&ds);
    // 3. sparse algorithms over the complete graph
    let complete: Vec<demst::graph::Edge> = (0..n as u32)
        .flat_map(|i| {
            let ds = &ds;
            let metric = &metric;
            ((i + 1)..n as u32).map(move |j| {
                use demst::geometry::Metric;
                demst::graph::Edge::new(i, j, metric.dist(ds.row(i as usize), ds.row(j as usize)))
            })
        })
        .collect();
    let t3 = kruskal(n, &complete);
    let t4 = prim_sparse(n, &complete);
    let t5 = boruvka_sparse(n, &complete);
    // 4. serial decomposed (the paper), several partitionings
    let t7 = decomposed_mst(
        &ds,
        &DecompConfig { parts: 6, strategy: PartitionStrategy::RandomShuffle, seed: 3, keep_pair_trees: false },
        &PrimDense::sq_euclid(),
    )
    .mst;
    // 5. distributed decomposed
    let t8 = run_distributed(
        &ds,
        &RunConfig { parts: 5, workers: 3, kernel: KernelChoice::BoruvkaRust, ..Default::default() },
    )
    .unwrap()
    .mst;

    let expect = normalize_tree(&t1);
    for (name, t) in [
        ("boruvka-dense", &t2),
        ("kruskal", &t3),
        ("prim-sparse", &t4),
        ("boruvka-sparse", &t5),
        ("decomposed-serial", &t7),
        ("decomposed-distributed", &t8),
    ] {
        assert!(is_spanning_tree(n, t), "{name} spanning");
        assert_eq!(expect, normalize_tree(t), "{name} != prim-dense");
    }

    // SLINK's pointer-representation tree is weight-equivalent to the MST
    // (same weight multiset => same dendrogram) but its edge set may differ.
    let t6 = slink_mst(&ds, &metric);
    assert!(is_spanning_tree(n, &t6), "slink spanning");
    let mut wa: Vec<f32> = t1.iter().map(|e| e.w).collect();
    let mut wb: Vec<f32> = t6.iter().map(|e| e.w).collect();
    wa.sort_by(f32::total_cmp);
    wb.sort_by(f32::total_cmp);
    assert_eq!(wa, wb, "slink weight multiset equals MST weights");
    assert_eq!(
        mst_to_dendrogram(n, &t1).heights(),
        mst_to_dendrogram(n, &t6).heights(),
        "identical dendrogram heights"
    );
}

#[test]
fn dendrogram_from_distributed_equals_slink() {
    let spec = EmbeddingSpec { n: 300, d: 48, latent: 6, k: 10, cluster_std: 0.3, noise: 0.01 };
    let (ds, _) = embedding_like(&spec, Pcg64::seeded(1001));
    let out = run_distributed(
        &ds,
        &RunConfig { parts: 4, workers: 2, kernel: KernelChoice::BoruvkaRust, ..Default::default() },
    )
    .unwrap();
    let dist_dendro = mst_to_dendrogram(ds.n, &out.mst);
    let slink_dendro = slink(&ds, &PlainMetric(MetricKind::SqEuclid));
    // merge heights identical (to float tolerance)
    let (ha, hb) = (dist_dendro.heights(), slink_dendro.heights());
    assert_eq!(ha.len(), hb.len());
    for (a, b) in ha.iter().zip(&hb) {
        assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "height {a} vs {b}");
    }
    // identical flat clusterings at several k
    for k in [2usize, 5, 10, 25] {
        let la = dist_dendro.cut_to_k(k);
        let lb = slink_dendro.cut_to_k(k);
        assert!(same_partition(&la, &lb), "k={k}");
    }
}

#[test]
fn config_file_to_run_pipeline() {
    let toml = r#"
name = "integration"
parts = 3
workers = 2
kernel = "prim-dense"
seed = 5

[data]
kind = "blobs"
n = 90
d = 8
clusters = 3
std = 0.2
spread = 9.0
"#;
    let cfg = RunConfig::from_toml(toml).unwrap();
    let (ds, truth) = build_dataset(&cfg).unwrap();
    let out = run_distributed(&ds, &cfg).unwrap();
    assert!(is_spanning_tree(ds.n, &out.mst));
    let labels = mst_to_dendrogram(ds.n, &out.mst).cut_to_k(3);
    assert!(same_partition(&labels, &truth.unwrap()), "3 tight blobs recovered");
}

#[test]
fn npy_roundtrip_through_pipeline() {
    let dir = std::env::temp_dir().join("demst_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("points.npy");
    let (ds, _) = gaussian_blobs_labeled(
        &BlobSpec { n: 80, d: 6, k: 4, std: 0.3, spread: 8.0 },
        Pcg64::seeded(1002),
    );
    demst::data::npy::write_npy(&path, &ds).unwrap();

    let mut cfg = RunConfig::default();
    cfg.data.kind = "npy".into();
    cfg.data.path = Some(path);
    cfg.parts = 4;
    cfg.kernel = KernelChoice::PrimDense;
    let (loaded, _) = build_dataset(&cfg).unwrap();
    assert_eq!(loaded, ds);
    let out = run_distributed(&loaded, &cfg).unwrap();
    let expect = PrimDense::sq_euclid().mst(&ds);
    assert_eq!(normalize_tree(&expect), normalize_tree(&out.mst));
}

#[test]
fn net_simulation_delays_increase_wall_not_result() {
    let (ds, _) = gaussian_blobs_labeled(
        &BlobSpec { n: 100, d: 8, k: 4, std: 0.3, spread: 6.0 },
        Pcg64::seeded(1003),
    );
    // affinity off: the dense byte model is deterministic, so the two runs
    // must charge identical traffic regardless of scheduling interleavings
    let mut cfg = RunConfig {
        parts: 4,
        workers: 2,
        kernel: KernelChoice::PrimDense,
        affinity: false,
        ..Default::default()
    };
    let fast = run_distributed(&ds, &cfg).unwrap();
    cfg.net.simulate_delays = true;
    cfg.net.latency_us = 3000; // 3ms per message, 13 messages minimum
    let slow = run_distributed(&ds, &cfg).unwrap();
    assert_eq!(normalize_tree(&fast.mst), normalize_tree(&slow.mst));
    assert!(
        slow.metrics.wall > fast.metrics.wall,
        "latency model must show up in wallclock: fast={:?} slow={:?}",
        fast.metrics.wall,
        slow.metrics.wall
    );
    assert_eq!(fast.metrics.scatter_bytes, slow.metrics.scatter_bytes, "same traffic");
}

#[test]
fn metrics_account_scatter_exactly() {
    // dense-model (affinity off) strategy-independent invariant:
    // scatter bytes = Σ_jobs (16 + |S|*4 + |S|*d*4)
    let (ds, _) = gaussian_blobs_labeled(
        &BlobSpec { n: 120, d: 10, k: 4, std: 0.3, spread: 6.0 },
        Pcg64::seeded(1004),
    );
    for parts in [2usize, 3, 5] {
        let cfg = RunConfig {
            parts,
            workers: 2,
            kernel: KernelChoice::PrimDense,
            strategy: PartitionStrategy::RoundRobin,
            affinity: false,
            ..Default::default()
        };
        let out = run_distributed(&ds, &cfg).unwrap();
        let sizes = demst::decomp::partition_indices(&ds, parts, cfg.strategy, cfg.seed);
        let mut expect = 0u64;
        for j in 1..parts {
            for i in 0..j {
                let m = (sizes[i].len() + sizes[j].len()) as u64;
                expect += 16 + m * 4 + m * ds.d as u64 * 4;
            }
        }
        assert_eq!(out.metrics.scatter_bytes, expect, "parts={parts}");
    }
}

#[test]
fn cosine_metric_pipeline() {
    // generalized geometric MST: cosine distance end-to-end
    let spec = EmbeddingSpec { n: 120, d: 32, latent: 5, k: 6, cluster_std: 0.3, noise: 0.01 };
    let (ds, _) = embedding_like(&spec, Pcg64::seeded(1005));
    let cfg = RunConfig {
        parts: 4,
        workers: 2,
        kernel: KernelChoice::PrimDense,
        metric: MetricKind::Cosine,
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg).unwrap();
    assert!(is_spanning_tree(ds.n, &out.mst));
    let oracle = slink_mst(&ds, &PlainMetric(MetricKind::Cosine));
    let (a, b) = (total_weight(&oracle), total_weight(&out.mst));
    assert!((a - b).abs() < 1e-5 * (1.0 + a.abs()), "cosine: slink={a} dist={b}");
}

#[test]
fn bipartite_pair_kernel_exact_across_metrics_with_fewer_evals() {
    // Acceptance bar for the bipartite-merge kernel: identical MSF as the
    // dense pair kernel AND the scalar-Prim oracle on sq-Euclid, cosine,
    // and Manhattan; strictly fewer distance evaluations than the dense
    // pair path for |P| >= 3 (the cycle-property filter computes each
    // subset's internal structure once instead of |P|-1 times).
    use demst::config::PairKernelChoice;
    use demst::dense::PrimScalar;

    // integer coordinates: every arithmetic path is float-exact
    let mut rng = Pcg64::seeded(2000);
    let (n, d) = (96, 6);
    let data: Vec<f32> = (0..n * d).map(|_| rng.next_bounded(31) as f32 - 15.0).collect();
    let ds = Dataset::new(n, d, data);

    for metric in [MetricKind::SqEuclid, MetricKind::Cosine, MetricKind::Manhattan] {
        let oracle = PrimScalar::new(metric).mst(&ds);
        for parts in [2usize, 3, 4, 6] {
            let mut cfg = RunConfig {
                parts,
                workers: 2,
                kernel: KernelChoice::PrimDense,
                metric,
                ..Default::default()
            };
            let dense = run_distributed(&ds, &cfg).unwrap();
            cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
            let bip = run_distributed(&ds, &cfg).unwrap();
            assert_eq!(
                normalize_tree(&oracle),
                normalize_tree(&bip.mst),
                "{metric:?} parts={parts}: bipartite vs scalar oracle"
            );
            assert_eq!(
                normalize_tree(&dense.mst),
                normalize_tree(&bip.mst),
                "{metric:?} parts={parts}: bipartite vs dense pair kernel"
            );
            let nn = ds.n as u64;
            assert_eq!(
                bip.metrics.dist_evals,
                nn * (nn - 1) / 2,
                "{metric:?} parts={parts}: bipartite run costs exactly C(n,2) evals"
            );
            if parts >= 3 {
                assert!(
                    bip.metrics.dist_evals < dense.metrics.dist_evals,
                    "{metric:?} parts={parts}: {} !< {}",
                    bip.metrics.dist_evals,
                    dense.metrics.dist_evals
                );
            }
        }
    }
}

/// Same partition up to label renaming.
fn same_partition(a: &[u32], b: &[u32]) -> bool {
    use std::collections::HashMap;
    if a.len() != b.len() {
        return false;
    }
    let (mut fwd, mut bwd) = (HashMap::new(), HashMap::new());
    a.iter().zip(b).all(|(&x, &y)| {
        *fwd.entry(x).or_insert(y) == y && *bwd.entry(y).or_insert(x) == x
    })
}
