//! CLI-level tests: drive the built `demst` binary end-to-end.

use std::path::PathBuf;
use std::process::Command;

fn demst() -> Command {
    Command::new(env!("CARGO_BIN_EXE_demst"))
}

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join("demst_cli_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_lists_subcommands() {
    let out = demst().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["run", "worker", "partition", "dendrogram", "gen", "info", "selftest"] {
        assert!(text.contains(cmd), "help mentions {cmd}");
    }
}

/// `demst partition` writes shard files + a loadable manifest, prints a
/// pair-covering assignment, and a sharded CLI run over real worker
/// processes returns the byte-identical MST CSV as `--transport sim`.
#[test]
fn partition_then_sharded_run_matches_sim() {
    let dir = tmpdir().join("cli_shards");
    std::fs::create_dir_all(&dir).unwrap();
    let data_args = [
        "--data", "blobs", "--n", "96", "--d", "5", "--clusters", "3", "--parts", "4",
        "--seed", "11", "--strategy", "block",
    ];
    let out = demst()
        .arg("partition")
        .args(data_args)
        .args(["--name", "clitest", "--plan-workers", "2"])
        .arg("--out-dir")
        .arg(&dir)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("4 shards"), "{stdout}");
    assert!(stdout.contains("--shard-ids"), "prints the covering assignment: {stdout}");
    let manifest = dir.join("clitest.manifest.toml");
    assert!(manifest.is_file());
    for k in 0..4 {
        assert!(dir.join(format!("clitest.shard{k}.bin")).is_file());
    }

    // sim reference of the same dataset/partition
    let sim_csv = tmpdir().join("cli_shard_sim.csv");
    let out = demst()
        .arg("run")
        .args(data_args)
        .args(["--workers", "2", "--pair-kernel", "bipartite"])
        .arg("--out-mst")
        .arg(&sim_csv)
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));

    // sharded leader + 2 external shard-resident workers on loopback
    let tcp_csv = tmpdir().join("cli_shard_tcp.csv");
    let mut leader = demst()
        .arg("run")
        .args(["--workers", "2", "--pair-kernel", "bipartite"])
        .args(["--transport", "tcp", "--listen", "127.0.0.1:0"])
        .arg("--shard")
        .arg(&manifest)
        .arg("--out-mst")
        .arg(&tcp_csv)
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    // scrape the bound address from the leader's first line
    let addr = {
        use std::io::{BufRead, BufReader};
        let stdout = leader.stdout.take().unwrap();
        let mut reader = BufReader::new(stdout);
        let mut addr = None;
        let mut line = String::new();
        while reader.read_line(&mut line).unwrap() > 0 {
            if let Some(at) = line.find("listening on ") {
                let rest = &line[at + "listening on ".len()..];
                addr = Some(rest.split_whitespace().next().unwrap().to_string());
                break;
            }
            line.clear();
        }
        // keep draining in the background so the leader never blocks on a
        // full stdout pipe
        std::thread::spawn(move || {
            let mut sink = String::new();
            loop {
                sink.clear();
                if reader.read_line(&mut sink).unwrap_or(0) == 0 {
                    break;
                }
            }
        });
        addr.expect("leader printed its bound address")
    };
    let workers: Vec<_> = [["0", "1", "2", "3"].join(","), ["2", "3"].join(",")]
        .into_iter()
        .map(|ids| {
            demst()
                .args(["worker", "--connect", &addr])
                .arg("--shard")
                .arg(&manifest)
                .arg("--shard-ids")
                .arg(&ids)
                .spawn()
                .unwrap()
        })
        .collect();
    let status = leader.wait().unwrap();
    assert!(status.success(), "sharded leader failed");
    for mut w in workers {
        assert!(w.wait().unwrap().success(), "worker failed");
    }
    let sim = std::fs::read(&sim_csv).unwrap();
    let tcp = std::fs::read(&tcp_csv).unwrap();
    assert_eq!(sim, tcp, "sharded tcp MST CSV must be byte-identical to sim");
}

#[test]
fn worker_rejects_shard_ids_without_manifest() {
    let out = demst()
        .args(["worker", "--connect", "127.0.0.1:1", "--shard-ids", "0,1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--shard-ids requires --shard"), "{err}");
}

#[test]
fn dendrogram_subcommand_writes_merges_and_stable_labels() {
    let merges_csv = tmpdir().join("dendro_merges.csv");
    let stable_csv = tmpdir().join("dendro_stable.csv");
    let labels_csv = tmpdir().join("dendro_k_labels.csv");
    let out = demst()
        .args([
            "dendrogram", "--data", "blobs", "--n", "90", "--d", "6", "--clusters", "3",
            "--parts", "3", "--pair-kernel", "bipartite-merge", "--k", "3",
            "--min-cluster-size", "5", "--verify",
        ])
        .arg("--out-merges")
        .arg(&merges_csv)
        .arg("--out-labels")
        .arg(&labels_csv)
        .arg("--out-stable")
        .arg(&stable_csv)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("verify: OK"), "{stdout}");
    assert!(stdout.contains("merges written to"), "{stdout}");
    assert!(stdout.contains("stable clusters"), "{stdout}");
    let merges = std::fs::read_to_string(&merges_csv).unwrap();
    assert_eq!(merges.lines().count(), 90, "header + 89 merges");
    assert!(merges.starts_with("cluster_a,cluster_b,height,size"), "{merges}");
    let labels = std::fs::read_to_string(&labels_csv).unwrap();
    assert_eq!(labels.lines().count(), 91, "header + 90 labels");
    let stable = std::fs::read_to_string(&stable_csv).unwrap();
    assert_eq!(stable.lines().count(), 91, "header + 90 stable labels");
}

#[test]
fn dendrogram_requires_out_merges() {
    let out =
        demst().args(["dendrogram", "--data", "blobs", "--n", "40", "--d", "4"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("out-merges"), "{err}");
}

#[test]
fn run_bipartite_stream_reduce_end_to_end() {
    let out = demst()
        .args([
            "run", "--data", "blobs", "--n", "100", "--d", "8", "--parts", "4",
            "--pair-kernel", "bipartite", "--stream-reduce", "--verify",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("verify: OK"), "{stdout}");
    assert!(stdout.contains("pair_kernel=bipartite-merge"), "{stdout}");
    assert!(stdout.contains("stream_reduce"), "{stdout}");
    assert!(stdout.contains("phases:"), "{stdout}");
    // bipartite + streaming always has panel probes and folds to report
    assert!(stdout.contains("locality:"), "{stdout}");
    assert!(stdout.contains("panel_cache="), "{stdout}");
    assert!(stdout.contains("folds="), "{stdout}");
    assert!(stdout.contains("workers:"), "{stdout}");
    // the SIMD panel path reports which ISA it dispatched to
    assert!(stdout.contains("kernel: isa="), "{stdout}");
    assert!(stdout.contains("lanes="), "{stdout}");
}

#[test]
fn run_panel_simd_off_is_bit_identical_and_reported() {
    let base = [
        "run", "--data", "blobs", "--n", "90", "--d", "7", "--parts", "3",
        "--pair-kernel", "bipartite",
    ];
    let simd = demst().args(base).output().unwrap();
    assert!(simd.status.success(), "stderr: {}", String::from_utf8_lossy(&simd.stderr));
    let scalar = demst().args(base).args(["--no-panel-simd", "--panel-threads", "1"]).output().unwrap();
    assert!(scalar.status.success(), "stderr: {}", String::from_utf8_lossy(&scalar.stderr));
    let (so, co) = (
        String::from_utf8_lossy(&simd.stdout).to_string(),
        String::from_utf8_lossy(&scalar.stdout).to_string(),
    );
    // forced-scalar path reports itself and why
    assert!(co.contains("kernel: isa=scalar lanes=1"), "{co}");
    assert!(co.contains("fallback:"), "{co}");
    // same dataset, same tree weight to the printed digit — the bit-identity
    // contract seen from the CLI
    let weight = |s: &str| {
        s.lines().find(|l| l.starts_with("mst:")).map(|l| l.to_string()).expect("mst line")
    };
    assert_eq!(weight(&so), weight(&co), "SIMD and scalar runs must agree exactly");
    // panel_threads out of range fails validation with a clear error
    let bad = demst().args(base).args(["--panel-threads", "257"]).output().unwrap();
    assert!(!bad.status.success());
    let err = String::from_utf8_lossy(&bad.stderr);
    assert!(err.contains("1..=256"), "{err}");
}

#[test]
fn run_no_affinity_flag_accepted() {
    let out = demst()
        .args(["run", "--data", "blobs", "--n", "60", "--d", "4", "--parts", "3", "--no-affinity"])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(!stdout.contains("scatter_saved="), "dense model saves nothing: {stdout}");
}

#[test]
fn run_transport_tcp_misconfigurations_fail_with_one_line_errors() {
    // tcp without --listen
    let out = demst()
        .args(["run", "--transport", "tcp", "--workers", "2", "--n", "64", "--d", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--listen"), "{err}");
    // tcp without an explicit worker count
    let out = demst()
        .args(["run", "--transport", "tcp", "--listen", "127.0.0.1:0", "--n", "64", "--d", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("worker count"), "{err}");
    // tcp with a single partition subset
    let out = demst()
        .args([
            "run", "--transport", "tcp", "--listen", "127.0.0.1:0", "--workers", "2",
            "--parts", "1", "--n", "64", "--d", "4",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("parts >= 2"), "{err}");
    // unknown transport name
    let out = demst()
        .args(["run", "--transport", "quic", "--n", "64", "--d", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown transport"), "{err}");
}

#[test]
fn worker_requires_connect() {
    let out = demst().arg("worker").output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--connect"), "{err}");
}

/// The acceptance-criterion run: `demst run --transport tcp` against two
/// spawned `demst worker` processes on loopback returns the identical MST
/// (same CSV, byte for byte) as `--transport sim` for the same seed.
#[test]
fn run_transport_tcp_loopback_matches_sim_mst() {
    let tcp_csv = tmpdir().join("transport_tcp_mst.csv");
    let sim_csv = tmpdir().join("transport_sim_mst.csv");
    let data_args = [
        "--data", "blobs", "--n", "120", "--d", "6", "--clusters", "4", "--parts", "4",
        "--workers", "2", "--seed", "9", "--pair-kernel", "bipartite",
    ];
    let out = demst()
        .arg("run")
        .args(data_args)
        .args(["--transport", "tcp", "--listen", "127.0.0.1:0", "--spawn-workers"])
        .arg("--out-mst")
        .arg(&tcp_csv)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("transport=tcp"), "{stdout}");
    assert!(stdout.contains("spawned 2 local"), "{stdout}");

    let out = demst().arg("run").args(data_args).arg("--out-mst").arg(&sim_csv).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let (tcp, sim) = (
        std::fs::read_to_string(&tcp_csv).unwrap(),
        std::fs::read_to_string(&sim_csv).unwrap(),
    );
    assert_eq!(tcp, sim, "tcp and sim MST CSVs must be byte-identical");
    assert_eq!(tcp.lines().count(), 120, "header + 119 edges");
}

#[test]
fn no_args_prints_help_and_succeeds() {
    let out = demst().output().unwrap();
    assert!(out.status.success());
}

#[test]
fn unknown_subcommand_fails_with_message() {
    let out = demst().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown subcommand"), "{err}");
}

#[test]
fn unknown_option_shows_usage() {
    let out = demst().args(["run", "--bogus-flag"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown option"), "{err}");
    assert!(err.contains("--parts"), "usage listed: {err}");
}

#[test]
fn gen_then_run_roundtrip() {
    let npy = tmpdir().join("cli_points.npy");
    let out = demst()
        .args(["gen", "--kind", "blobs", "--n", "120", "--d", "8", "--clusters", "4", "--out"])
        .arg(&npy)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(npy.is_file());

    let mst_csv = tmpdir().join("cli_mst.csv");
    let labels_csv = tmpdir().join("cli_labels.csv");
    let out = demst()
        .args(["run", "--data", "npy", "--kernel", "prim-dense", "--parts", "3", "--verify", "--k", "4"])
        .arg("--path")
        .arg(&npy)
        .arg("--out-mst")
        .arg(&mst_csv)
        .arg("--out-labels")
        .arg(&labels_csv)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("verify: OK"), "{stdout}");
    assert!(stdout.contains("mst: 119 edges"), "{stdout}");
    // outputs written and well-formed
    let mst = std::fs::read_to_string(&mst_csv).unwrap();
    assert_eq!(mst.lines().count(), 120, "header + 119 edges");
    let labels = std::fs::read_to_string(&labels_csv).unwrap();
    assert_eq!(labels.lines().count(), 121, "header + 120 labels");
}

#[test]
fn run_with_config_file_and_override() {
    let cfg = tmpdir().join("cli_cfg.toml");
    std::fs::write(
        &cfg,
        r#"
parts = 3
workers = 2
kernel = "prim-dense"
verify = true

[data]
kind = "blobs"
n = 90
d = 6
clusters = 3
"#,
    )
    .unwrap();
    let out = demst()
        .args(["run", "--config"])
        .arg(&cfg)
        .args(["--parts", "5"]) // CLI overrides file
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("parts=5"), "override applied: {stdout}");
    assert!(stdout.contains("verify: OK"), "{stdout}");
}

#[test]
fn run_rejects_invalid_config_combination() {
    let out = demst()
        .args(["run", "--kernel", "xla", "--metric", "cosine", "--n", "64", "--d", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("Euclidean"), "{err}");
}

#[cfg(not(feature = "backend-xla"))]
#[test]
fn run_with_xla_kernel_falls_back_and_reports() {
    // Default build has no PJRT: requesting boruvka-xla degrades to the
    // blocked Rust provider and says so on stdout.
    let out = demst()
        .args(["run", "--kernel", "xla", "--data", "blobs", "--n", "80", "--d", "6", "--parts", "2"])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("kernel fallback:"), "{stdout}");
    assert!(stdout.contains("backend-xla"), "{stdout}");
    assert!(stdout.contains("mst: 79 edges"), "{stdout}");
}

#[test]
fn run_supports_manhattan_metric_end_to_end() {
    // The metric-generic blocked kernels serve non-Euclidean metrics through
    // the same distributed path, with verification against the SLINK oracle.
    let out = demst()
        .args([
            "run", "--kernel", "boruvka-rust", "--metric", "Manhattan", "--data", "blobs",
            "--n", "90", "--d", "5", "--parts", "3", "--verify",
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("verify: OK"), "{stdout}");
}

#[test]
fn info_reports_artifacts_when_present() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").is_file() {
        eprintln!("SKIP: artifacts not built");
        return;
    }
    let out = demst().args(["info", "--artifacts"]).arg(&dir).output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("cheapest_edge"), "{stdout}");
    assert!(stdout.contains("present"), "{stdout}");
}
