//! Transport parity: the real TCP transport must be byte- and
//! tree-equivalent to the simulated fabric.
//!
//! For a fixed seed/dataset, a loopback `tcp` run returns the bit-identical
//! MST edge list as `sim`; its scatter/gather counters — fed by **actual
//! encoded frame sizes** on the sockets — equal the simulated charges
//! (exactly, where the claim schedule is deterministic; via the
//! `charged + saved == dense model` invariant under concurrent stealing);
//! and the handshake appears only as control-plane traffic, which the
//! simulation deliberately does not model.

use demst::config::{KernelChoice, PairKernelChoice, RunConfig, TransportChoice};
use demst::coordinator::run_distributed;
use demst::data::Dataset;
use demst::exec::PooledRun;
use demst::geometry::MetricKind;
use demst::mst::normalize_tree;
use demst::net::worker::WorkerOptions;
use demst::net::{launch, worker};
use demst::util::prng::Pcg64;
use std::net::TcpListener;
use std::path::PathBuf;
use std::time::Duration;

fn float_dataset(seed: u64, n: usize, d: usize) -> Dataset {
    let mut rng = Pcg64::seeded(seed);
    let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
    Dataset::new(n, d, data)
}

fn base_cfg(parts: usize, workers: usize) -> RunConfig {
    RunConfig { parts, workers, kernel: KernelChoice::PrimDense, ..Default::default() }
}

/// Run `cfg` over real loopback sockets with in-thread workers serving the
/// far ends (the same `net::worker::serve` loop `demst worker` runs).
fn tcp_run(ds: &Dataset, cfg: &RunConfig) -> PooledRun {
    let mut cfg = cfg.clone();
    cfg.transport = TransportChoice::Tcp;
    cfg.listen = Some("127.0.0.1:0".into());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let n = demst::exec::resolve_workers(&cfg);
    let handles: Vec<_> = (0..n)
        .map(|_| {
            std::thread::spawn(move || worker::run(&addr.to_string(), Duration::from_secs(10)))
        })
        .collect();
    let run = launch::serve(ds, &cfg, &listener).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    run
}

/// Single worker: the claim schedule is deterministic, so sim and tcp must
/// agree **exactly** — bit-identical trees and equal scatter/gather byte
/// counters — for both pair kernels across every metric.
#[test]
fn tcp_matches_sim_bit_identical_trees_and_counters() {
    for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
        for metric in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            let ds = float_dataset(900, 48, 5);
            let mut cfg = base_cfg(4, 1);
            cfg.pair_kernel = pair_kernel;
            cfg.metric = metric;
            let sim = run_distributed(&ds, &cfg).unwrap();
            let tcp = tcp_run(&ds, &cfg);
            let tag = format!("{pair_kernel:?} {metric:?}");
            assert_eq!(
                normalize_tree(&sim.mst),
                normalize_tree(&tcp.mst),
                "{tag}: trees must be bit-identical"
            );
            assert_eq!(
                sim.metrics.scatter_bytes, tcp.metrics.scatter_bytes,
                "{tag}: sim charges == tcp frame bytes (scatter)"
            );
            assert_eq!(
                sim.metrics.gather_bytes, tcp.metrics.gather_bytes,
                "{tag}: sim charges == tcp frame bytes (gather)"
            );
            assert_eq!(
                sim.metrics.scatter_saved_bytes, tcp.metrics.scatter_saved_bytes,
                "{tag}: resident-set savings agree"
            );
            assert_eq!(sim.metrics.dist_evals, tcp.metrics.dist_evals, "{tag}");
            assert_eq!(tcp.metrics.transport, "tcp");
            // the handshake is real control traffic the simulation does not
            // model — strictly more control bytes on the wire
            assert!(
                tcp.metrics.control_bytes > sim.metrics.control_bytes,
                "{tag}: handshake counted as control"
            );
        }
    }
}

/// Two workers, dense byte model (affinity off): per-job payloads are fixed
/// regardless of which worker claims what, so the counters must still match
/// exactly under a nondeterministic schedule.
#[test]
fn tcp_dense_model_counters_exact_under_two_workers() {
    let ds = float_dataset(901, 60, 6);
    let mut cfg = base_cfg(4, 2);
    cfg.affinity = false;
    let sim = run_distributed(&ds, &cfg).unwrap();
    let tcp = tcp_run(&ds, &cfg);
    assert_eq!(normalize_tree(&sim.mst), normalize_tree(&tcp.mst));
    assert_eq!(sim.metrics.scatter_bytes, tcp.metrics.scatter_bytes);
    assert_eq!(sim.metrics.gather_bytes, tcp.metrics.gather_bytes);
    assert_eq!(tcp.metrics.scatter_saved_bytes, 0, "dense model saves nothing");
}

/// Two workers with affinity: the claim schedule (and hence the residency
/// history) is racy, but the resident-set invariant must hold on the real
/// wire too: actual frame bytes + modeled savings == the dense model.
#[test]
fn tcp_affinity_invariant_holds_on_the_wire() {
    let ds = float_dataset(902, 64, 5);
    for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
        let mut cfg = base_cfg(4, 2);
        cfg.pair_kernel = pair_kernel;
        cfg.affinity = false;
        let dense_model = run_distributed(&ds, &cfg).unwrap();
        cfg.affinity = true;
        let tcp = tcp_run(&ds, &cfg);
        assert_eq!(
            normalize_tree(&dense_model.mst),
            normalize_tree(&tcp.mst),
            "{pair_kernel:?}"
        );
        assert_eq!(
            tcp.metrics.scatter_bytes + tcp.metrics.scatter_saved_bytes,
            dense_model.metrics.scatter_bytes,
            "{pair_kernel:?}: charged frames + saved == dense model"
        );
        assert!(tcp.metrics.scatter_bytes <= dense_model.metrics.scatter_bytes);
    }
}

/// Reduce + streaming modes compose with the remote transport: the worker
/// processes ⊕-fold locally (Ack per job, folded tree in the final
/// WorkerDone) and the tree is unchanged.
#[test]
fn tcp_reduce_and_stream_modes_match_sim() {
    let ds = float_dataset(903, 56, 4);
    for (reduce, stream) in [(true, false), (false, true), (true, true)] {
        let mut cfg = base_cfg(4, 2);
        cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
        cfg.reduce_tree = reduce;
        cfg.stream_reduce = stream;
        let sim = run_distributed(&ds, &cfg).unwrap();
        let tcp = tcp_run(&ds, &cfg);
        assert_eq!(
            normalize_tree(&sim.mst),
            normalize_tree(&tcp.mst),
            "reduce={reduce} stream={stream}"
        );
    }
}

/// Real `demst worker` **processes** (not threads) against a library-side
/// leader: the acceptance-criterion shape, minus the CLI front-end (covered
/// in tests/cli.rs).
#[test]
fn tcp_with_spawned_worker_processes() {
    let ds = float_dataset(904, 52, 4);
    let mut cfg = base_cfg(4, 2);
    cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
    let sim = run_distributed(&ds, &cfg).unwrap();

    cfg.transport = TransportChoice::Tcp;
    cfg.listen = Some("127.0.0.1:0".into());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let children: Vec<_> = (0..2)
        .map(|_| {
            std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
                .args(["worker", "--connect", &addr])
                .spawn()
                .unwrap()
        })
        .collect();
    let tcp = launch::serve(&ds, &cfg, &listener).unwrap();
    for mut child in children {
        let status = child.wait().unwrap();
        assert!(status.success(), "worker process exited with {status}");
    }
    assert_eq!(normalize_tree(&sim.mst), normalize_tree(&tcp.mst));
    assert_eq!(sim.metrics.scatter_bytes + sim.metrics.scatter_saved_bytes,
               tcp.metrics.scatter_bytes + tcp.metrics.scatter_saved_bytes,
               "both residency histories reconcile to the same dense model");
}

/// A worker pointed at a dead address fails with an actionable error once
/// its retry window lapses (instead of hanging) — and the error names the
/// unreachable address. The backoff is configurable and bounded: even a
/// large initial backoff cannot stretch the wait past the window.
#[test]
fn worker_connect_retry_times_out() {
    // bind-then-drop: the port is (very likely) closed again
    let port = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let err = worker::run(&addr, Duration::from_millis(300)).unwrap_err();
    assert!(err.to_string().contains("could not connect"), "{err:#}");
    assert!(err.to_string().contains(&addr), "error names the address: {err:#}");

    // custom timeout + backoff through WorkerOptions: the sleep is clamped
    // to the remaining window, so this returns in ~200 ms, not 5 s
    let t0 = std::time::Instant::now();
    let opts = WorkerOptions {
        connect_timeout: Duration::from_millis(200),
        connect_backoff: Duration::from_secs(5),
        ..Default::default()
    };
    let err = worker::run_with(&addr, &opts).unwrap_err();
    assert!(err.to_string().contains(&addr), "{err:#}");
    assert!(
        t0.elapsed() < Duration::from_secs(3),
        "bounded backoff must not overshoot the window: {:?}",
        t0.elapsed()
    );
}

/// Write a shard set for `ds` into a fresh temp dir; returns the manifest
/// path and the manifest.
fn write_shards(tag: &str, ds: &Dataset, parts: usize) -> (demst::shard::Manifest, PathBuf) {
    let dir = std::env::temp_dir().join("demst_transport_shards").join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    demst::shard::write_dataset_shards(
        &dir,
        tag,
        ds,
        parts,
        demst::decomp::PartitionStrategy::Block,
        0,
        MetricKind::SqEuclid,
    )
    .unwrap()
}

/// Run a sharded leader over loopback with in-thread workers, each loading
/// the given shard subsets from disk.
fn sharded_run(
    cfg: &RunConfig,
    manifest: &demst::shard::Manifest,
    manifest_path: &PathBuf,
    assignments: &[Vec<u32>],
) -> PooledRun {
    let mut cfg = cfg.clone();
    cfg.transport = TransportChoice::Tcp;
    cfg.listen = Some("127.0.0.1:0".into());
    cfg.shard_manifest = Some(manifest_path.clone());
    cfg.workers = assignments.len();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handles: Vec<_> = assignments
        .iter()
        .map(|ids| {
            let opts = WorkerOptions {
                shards: Some((manifest_path.clone(), ids.clone())),
                ..Default::default()
            };
            std::thread::spawn(move || worker::run_with(&addr.to_string(), &opts))
        })
        .collect();
    let run = launch::serve_sharded(manifest, &cfg, &listener).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
    run
}

/// The acceptance-criterion core: on a sharded tcp run the leader never
/// materializes subset vectors (`leader_ingest_bytes == 0`), the worker
/// fleet holds the full payload locally, and the resulting MST is
/// bit-identical to the sim transport — across both pair kernels.
#[test]
fn tcp_sharded_leader_never_ships_vectors_and_stays_exact() {
    let ds = float_dataset(905, 64, 5);
    let (manifest, manifest_path) = write_shards("exact", &ds, 4);
    for pair_kernel in [PairKernelChoice::Dense, PairKernelChoice::BipartiteMerge] {
        let mut cfg = base_cfg(4, 2);
        cfg.strategy = demst::decomp::PartitionStrategy::Block;
        cfg.pair_kernel = pair_kernel;
        let sim = run_distributed(&ds, &cfg).unwrap();
        // worker 0 holds everything; worker 1 holds {2, 3} — all 6 pairs
        // are co-resident somewhere, so the run is schedulable
        let shard_run = sharded_run(
            &cfg,
            &manifest,
            &manifest_path,
            &[vec![0, 1, 2, 3], vec![2, 3]],
        );
        assert_eq!(
            normalize_tree(&sim.mst),
            normalize_tree(&shard_run.mst),
            "{pair_kernel:?}: sharded tree must be bit-identical to sim"
        );
        assert_eq!(
            shard_run.metrics.leader_ingest_bytes, 0,
            "{pair_kernel:?}: subset vectors must never pass through the leader"
        );
        assert!(shard_run.metrics.sharded);
        // the fleet loaded the full payload (plus the replicated shards)
        let full: u64 = (0..4)
            .map(|k| {
                let m = manifest.shards[k].ids.len();
                (m * 4 + m * ds.d * 4) as u64
            })
            .sum();
        let replicated: u64 = [2usize, 3]
            .iter()
            .map(|&k| {
                let m = manifest.shards[k].ids.len();
                (m * 4 + m * ds.d * 4) as u64
            })
            .sum();
        assert_eq!(shard_run.metrics.shard_local_bytes, full + replicated);
        // scatter may carry cached trees (bipartite) and frame headers but
        // no vector payload — far below the leader-resident byte model
        assert!(
            shard_run.metrics.scatter_bytes < sim.metrics.scatter_bytes,
            "{pair_kernel:?}: sharded scatter {} should undercut leader-resident {}",
            shard_run.metrics.scatter_bytes,
            sim.metrics.scatter_bytes
        );
        assert_eq!(shard_run.metrics.worker_failures, 0);
    }
}

/// An uncovered shard assignment (some subset pair co-resident nowhere)
/// must fail with an actionable error, not hang or mis-schedule.
#[test]
fn tcp_sharded_uncovered_assignment_fails_loudly() {
    let ds = float_dataset(906, 48, 4);
    let (manifest, manifest_path) = write_shards("uncovered", &ds, 4);
    let mut cfg = base_cfg(4, 2);
    cfg.strategy = demst::decomp::PartitionStrategy::Block;
    cfg.transport = TransportChoice::Tcp;
    cfg.listen = Some("127.0.0.1:0".into());
    cfg.shard_manifest = Some(manifest_path.clone());
    cfg.workers = 2;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    // pair (0, 3) is co-resident nowhere
    let handles: Vec<_> = [vec![0u32, 1, 2], vec![1, 2, 3]]
        .into_iter()
        .map(|ids| {
            let opts = WorkerOptions {
                shards: Some((manifest_path.clone(), ids)),
                ..Default::default()
            };
            std::thread::spawn(move || worker::run_with(&addr.to_string(), &opts))
        })
        .collect();
    let err = launch::serve_sharded(&manifest, &cfg, &listener).unwrap_err();
    assert!(err.to_string().contains("no worker holding both"), "{err:#}");
    for h in handles {
        // workers are released by the error path's shutdown broadcast
        let _ = h.join().unwrap();
    }
}

/// The leaderless data plane end to end, on real sockets: a sharded run
/// with three workers under `--reduce-topology tree` and `ring` must
/// produce the bit-identical MST as the simulated fabric **and** as the
/// default leader topology, while the leader link carries zero scatter
/// payload bytes (`leader_data_bytes == 0`: cached trees travel
/// worker↔worker, vectors never leave the shards) and the peer plane
/// witnesses real traffic (`peer_bytes > 0`).
#[test]
fn tcp_sharded_reduce_topologies_bypass_the_leader_bit_identically() {
    use demst::config::ReduceTopology;
    let ds = float_dataset(908, 72, 5);
    let (manifest, manifest_path) = write_shards("topology", &ds, 4);
    let mut cfg = base_cfg(4, 3);
    cfg.strategy = demst::decomp::PartitionStrategy::Block;
    cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
    cfg.reduce_tree = true;
    // worker 0 holds everything; 1 and 2 cover their pair neighborhoods
    let assignments = [vec![0u32, 1, 2, 3], vec![2, 3], vec![0, 1]];
    let leader_run = sharded_run(&cfg, &manifest, &manifest_path, &assignments);
    let baseline = normalize_tree(&leader_run.mst);
    for topology in [ReduceTopology::Tree, ReduceTopology::Ring] {
        cfg.reduce_topology = topology;
        let sim = run_distributed(&ds, &cfg).unwrap();
        let run = sharded_run(&cfg, &manifest, &manifest_path, &assignments);
        let tag = topology.name();
        assert_eq!(
            baseline,
            normalize_tree(&run.mst),
            "{tag}: fold topology must not change the tree"
        );
        assert_eq!(
            normalize_tree(&sim.mst),
            normalize_tree(&run.mst),
            "{tag}: tcp tree must be bit-identical to sim"
        );
        assert_eq!(run.metrics.reduce_topology, tag);
        assert_eq!(
            run.metrics.leader_data_bytes, 0,
            "{tag}: every payload byte must bypass the leader"
        );
        assert!(run.metrics.peer_bytes > 0, "{tag}: peer plane must carry traffic");
        assert!(run.metrics.leader_control_bytes > 0, "{tag}: directives are control");
        assert_eq!(run.metrics.worker_failures, 0, "{tag}");
    }
}

/// The liveness layer (short read deadlines + leader heartbeats) must be
/// invisible to the data plane: a healthy run under a sub-second deadline
/// produces the bit-identical tree, reconciles to the same dense byte
/// model, and demotes nobody.
#[test]
fn tcp_liveness_heartbeats_do_not_perturb_the_run() {
    let ds = float_dataset(909, 56, 5);
    let mut cfg = base_cfg(4, 2);
    cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
    let sim = run_distributed(&ds, &cfg).unwrap();
    cfg.net.liveness_timeout_ms = 900; // leader pulses every 300 ms
    let tcp = tcp_run(&ds, &cfg);
    assert_eq!(
        normalize_tree(&sim.mst),
        normalize_tree(&tcp.mst),
        "liveness must not change the tree"
    );
    // heartbeats are control-plane only: the scatter model still reconciles
    assert_eq!(
        sim.metrics.scatter_bytes + sim.metrics.scatter_saved_bytes,
        tcp.metrics.scatter_bytes + tcp.metrics.scatter_saved_bytes,
        "heartbeats must never carry data bytes"
    );
    assert_eq!(tcp.metrics.worker_failures, 0, "healthy links must not be demoted");
    assert_eq!(tcp.metrics.stalls_detected, 0);
}

/// Pipelined dispatch parity: window 1 (strict rendezvous) and window 2
/// (the default overlap) must move exactly the same bytes and produce the
/// bit-identical tree — the window changes *when* frames travel, never
/// which.
#[test]
fn tcp_pipeline_window_does_not_change_bytes_or_tree() {
    let ds = float_dataset(907, 56, 5);
    let mut cfg = base_cfg(4, 1);
    cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
    let sim = run_distributed(&ds, &cfg).unwrap();
    let mut runs = Vec::new();
    for window in [1usize, 2, 4] {
        cfg.pipeline_window = window;
        let run = tcp_run(&ds, &cfg);
        assert_eq!(
            normalize_tree(&sim.mst),
            normalize_tree(&run.mst),
            "window={window}: tree must stay bit-identical"
        );
        assert_eq!(run.metrics.pipeline_window, window as u32);
        runs.push(run);
    }
    for run in &runs[1..] {
        assert_eq!(
            runs[0].metrics.scatter_bytes, run.metrics.scatter_bytes,
            "window must not change scatter bytes"
        );
        assert_eq!(
            runs[0].metrics.gather_bytes, run.metrics.gather_bytes,
            "window must not change gather bytes"
        );
        assert_eq!(runs[0].metrics.messages, run.metrics.messages);
    }
    // and the single-worker window-1 run still matches the sim charges
    assert_eq!(sim.metrics.scatter_bytes, runs[0].metrics.scatter_bytes);
    assert_eq!(sim.metrics.gather_bytes, runs[0].metrics.gather_bytes);
}

/// Fleet metrics parity: the leader-merged histograms and counters a
/// metrics-armed tcp run assembles from its workers' shipped snapshots
/// must agree with what the in-process run records directly — same job
/// count in the latency histogram, same local-MST build count, and the
/// same deterministic distance-evaluation total (the wire is a transport,
/// not a different instrument).
#[test]
fn tcp_fleet_merged_metrics_match_in_process_recording() {
    use demst::obs::metrics::{Ctr, Hist};
    let ds = float_dataset(910, 60, 6);
    let mut cfg = base_cfg(4, 2);
    cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
    cfg.obs.metrics = true;
    let sim = run_distributed(&ds, &cfg).unwrap();
    let tcp = tcp_run(&ds, &cfg);
    assert_eq!(normalize_tree(&sim.mst), normalize_tree(&tcp.mst));

    let simf = sim.metrics.fleet_metrics.as_ref().expect("armed sim run carries a snapshot");
    let tcpf = tcp.metrics.fleet_metrics.as_ref().expect("armed tcp run carries a snapshot");

    // every pair job shows up exactly once in the latency histogram,
    // whichever side of the wire executed it
    let jobs = sim.metrics.jobs as u64;
    assert_eq!(tcp.metrics.jobs as u64, jobs);
    assert_eq!(simf.counter(Ctr::JobsCompleted), jobs);
    assert_eq!(tcpf.counter(Ctr::JobsCompleted), jobs);
    assert_eq!(simf.hist(Hist::JobLatency).count, jobs);
    assert_eq!(tcpf.hist(Hist::JobLatency).count, jobs);
    assert!(simf.slowest.is_some() && tcpf.slowest.is_some());

    // one local-MST build per partition on both transports
    assert_eq!(simf.hist(Hist::LocalMst).count, cfg.parts as u64);
    assert_eq!(tcpf.hist(Hist::LocalMst).count, cfg.parts as u64);

    // the deterministic counter: remote workers count the same distance
    // evaluations the in-process solvers do, and both reconcile with the
    // run-level total
    assert_eq!(simf.counter(Ctr::DistEvals), sim.metrics.dist_evals);
    assert_eq!(tcpf.counter(Ctr::DistEvals), tcp.metrics.dist_evals);
    assert_eq!(simf.counter(Ctr::DistEvals), tcpf.counter(Ctr::DistEvals));

    // both remote workers shipped a final snapshot; the in-process run has
    // no remote fleet to report
    assert_eq!(tcp.metrics.metrics_workers_reporting, 2);
    assert_eq!(sim.metrics.metrics_workers_reporting, 0);

    // the real wire moved real bytes — the link counters witnessed them
    assert!(tcpf.counter(Ctr::LinkRxBytes) > 0, "workers count received frames");
    assert!(tcpf.counter(Ctr::LinkTxBytes) > 0, "workers count sent frames");
}
