//! Property-based tests (hand-rolled harness in `demst::util::proptest`) on
//! the system's core invariants: Lemma 1, Theorem 1, MST uniqueness across
//! algorithms, dendrogram laws, union-find laws, and serialization
//! round-trips — over randomized inputs with deterministic replay seeds.

use demst::data::Dataset;
use demst::decomp::{decomposed_mst, DecompConfig, PartitionStrategy};
use demst::dense::{BoruvkaDense, DenseMst, PrimDense};
use demst::geometry::metric::PlainMetric;
use demst::geometry::{Metric, MetricKind};
use demst::graph::components::{is_forest, is_spanning_tree};
use demst::graph::{Edge, UnionFind};
use demst::mst::{boruvka_sparse, kruskal, normalize_tree, prim_sparse};
use demst::slink::mst_to_dendrogram;
use demst::util::proptest::{Gen, Runner};

/// Random integer-valued point set (exact arithmetic across code paths).
fn int_points(g: &mut Gen, n: usize, d: usize) -> Dataset {
    let data: Vec<f32> = (0..n * d)
        .map(|_| g.rng().next_bounded(33) as f32 - 16.0)
        .collect();
    Dataset::new(n, d, data)
}

fn complete_edges(ds: &Dataset) -> Vec<Edge> {
    let m = PlainMetric(MetricKind::SqEuclid);
    let mut edges = Vec::with_capacity(ds.n * (ds.n - 1) / 2);
    for i in 0..ds.n {
        for j in (i + 1)..ds.n {
            edges.push(Edge::new(i as u32, j as u32, m.dist(ds.row(i), ds.row(j))));
        }
    }
    edges
}

#[test]
fn prop_three_sparse_algorithms_agree() {
    Runner::new("sparse MST agreement", 0xA1, 40).run(|g| {
        let n = g.usize_in(2..40);
        let m = g.usize_in(1..n * 3);
        let edges: Vec<Edge> = (0..m)
            .map(|_| {
                let u = g.usize_in(0..n) as u32;
                let mut v = g.usize_in(0..n) as u32;
                if u == v {
                    v = (v + 1) % n as u32;
                }
                // coarse weights force ties
                Edge::new(u, v, g.usize_in(0..6) as f32)
            })
            .collect();
        let a = kruskal(n, &edges);
        let b = prim_sparse(n, &edges);
        let c = boruvka_sparse(n, &edges);
        assert!(is_forest(n, &a));
        assert_eq!(normalize_tree(&a), normalize_tree(&b));
        assert_eq!(normalize_tree(&a), normalize_tree(&c));
    });
}

#[test]
fn prop_lemma1_optimal_substructure() {
    // MSF(G)[S] ⊆ MSF(G[S]) for random S — the paper's Lemma 1 verbatim.
    Runner::new("lemma 1", 0xA2, 30).run(|g| {
        let n = g.usize_in(4..30);
        let d = g.usize_in(1..6);
        let ds = int_points(g, n, d);
        let full = complete_edges(&ds);
        let msf = kruskal(n, &full);
        // random vertex subset S (at least 2 vertices)
        let mut s: Vec<u32> = (0..n as u32).filter(|_| g.bool_p(0.5)).collect();
        if s.len() < 2 {
            s = vec![0, (n - 1) as u32];
        }
        let in_s = {
            let mut m = vec![false; n];
            for &v in &s {
                m[v as usize] = true;
            }
            m
        };
        // induced subgraph MSF
        let induced: Vec<Edge> = full
            .iter()
            .filter(|e| in_s[e.u as usize] && in_s[e.v as usize])
            .copied()
            .collect();
        let sub_msf = normalize_tree(&kruskal(n, &induced));
        // every MSF(G) edge inside S must appear in MSF(G[S])
        for e in &msf {
            if in_s[e.u as usize] && in_s[e.v as usize] {
                assert!(
                    sub_msf
                        .binary_search_by(|t| t.u.cmp(&e.u).then(t.v.cmp(&e.v)))
                        .is_ok(),
                    "edge ({},{}) in MSF(G)[S] but not in MSF(G[S])",
                    e.u,
                    e.v
                );
            }
        }
    });
}

#[test]
fn prop_theorem1_decomposition_exact() {
    Runner::new("theorem 1", 0xA3, 25).run(|g| {
        let n = g.usize_in(6..60);
        let d = g.usize_in(1..8);
        let ds = int_points(g, n, d);
        let parts = g.usize_in(2..(n / 2).max(3).min(8));
        let strategy = match g.usize_in(0..4) {
            0 => PartitionStrategy::Block,
            1 => PartitionStrategy::RoundRobin,
            2 => PartitionStrategy::RandomShuffle,
            _ => PartitionStrategy::KMeansLite,
        };
        let cfg = DecompConfig {
            parts,
            strategy,
            seed: g.rng().next_u64(),
            keep_pair_trees: false,
        };
        let out = decomposed_mst(&ds, &cfg, &PrimDense::sq_euclid());
        let expect = kruskal(n, &complete_edges(&ds));
        assert!(is_spanning_tree(n, &out.mst));
        assert_eq!(normalize_tree(&expect), normalize_tree(&out.mst), "{strategy:?} parts={parts}");
    });
}

#[test]
fn prop_dense_boruvka_equals_dense_prim() {
    Runner::new("dense kernels agree", 0xA4, 20).run(|g| {
        let n = g.usize_in(2..80);
        let d = g.usize_in(1..10);
        let ds = int_points(g, n, d);
        let a = PrimDense::sq_euclid().mst(&ds);
        let b = BoruvkaDense::new_rust(MetricKind::SqEuclid).mst(&ds);
        assert_eq!(normalize_tree(&a), normalize_tree(&b), "n={n} d={d}");
    });
}

#[test]
fn prop_exec_paths_and_pair_kernels_agree() {
    // The one engine, every route: serial dense decomp, pooled dense,
    // pooled bipartite-merge, and streamed-reduction runs must all produce
    // the identical MSF (edge set AND total weight) as the scalar-Prim
    // oracle, across metrics, partition strategies, and worker counts.
    use demst::config::{KernelChoice, PairKernelChoice, RunConfig};
    use demst::coordinator::run_distributed;
    use demst::dense::PrimScalar;
    use demst::mst::total_weight;

    Runner::new("exec paths agree", 0xAA, 12).run(|g| {
        let n = g.usize_in(8..56);
        let d = g.usize_in(1..7);
        let ds = int_points(g, n, d);
        // all four kinds, including Euclid's sqrt-at-emission path (int
        // coords this small have collision-free f32 sqrts, so the scalar
        // oracle still matches bit-for-bit)
        let metric = match g.usize_in(0..4) {
            0 => MetricKind::SqEuclid,
            1 => MetricKind::Euclid,
            2 => MetricKind::Cosine,
            _ => MetricKind::Manhattan,
        };
        let strategy = match g.usize_in(0..4) {
            0 => PartitionStrategy::Block,
            1 => PartitionStrategy::RoundRobin,
            2 => PartitionStrategy::RandomShuffle,
            _ => PartitionStrategy::KMeansLite,
        };
        let parts = g.usize_in(1..(n / 2).max(2).min(7));
        let seed = g.rng().next_u64();

        let oracle = PrimScalar::new(metric).mst(&ds);
        let expect = normalize_tree(&oracle);
        let expect_w = total_weight(&oracle);

        let serial = decomposed_mst(
            &ds,
            &DecompConfig { parts, strategy, seed, keep_pair_trees: false },
            &demst::dense::PrimDense::new(metric),
        );
        assert_eq!(expect, normalize_tree(&serial.mst), "serial {metric:?} {strategy:?}");

        let mut cfg = RunConfig {
            parts,
            strategy,
            seed,
            metric,
            workers: g.usize_in(1..4),
            kernel: KernelChoice::PrimDense,
            ..Default::default()
        };
        let pooled = run_distributed(&ds, &cfg).unwrap();
        assert_eq!(expect, normalize_tree(&pooled.mst), "pooled-dense {metric:?}");

        cfg.pair_kernel = PairKernelChoice::BipartiteMerge;
        cfg.stream_reduce = g.bool_p(0.5);
        cfg.reduce_tree = g.bool_p(0.3);
        let bip = run_distributed(&ds, &cfg).unwrap();
        assert_eq!(
            expect,
            normalize_tree(&bip.mst),
            "pooled-bipartite {metric:?} stream={} reduce={}",
            cfg.stream_reduce,
            cfg.reduce_tree
        );
        let got_w = total_weight(&bip.mst);
        assert!(
            (expect_w - got_w).abs() <= 1e-9 * (1.0 + expect_w.abs()),
            "weights: {expect_w} vs {got_w}"
        );

        // Locality-aware path (affinity is the default above): the same
        // tree as the dense byte model, never more scatter, and the saved
        // counter reconciles the two models byte-for-byte.
        assert!(cfg.affinity, "affinity routing must be the default");
        let mut dense_cfg = cfg.clone();
        dense_cfg.affinity = false;
        let dense_model = run_distributed(&ds, &dense_cfg).unwrap();
        assert_eq!(expect, normalize_tree(&dense_model.mst), "dense-model {metric:?}");
        assert_eq!(
            bip.metrics.scatter_bytes + bip.metrics.scatter_saved_bytes,
            dense_model.metrics.scatter_bytes,
            "charged + saved == dense model ({metric:?} parts={parts})"
        );
        assert!(bip.metrics.scatter_bytes <= dense_model.metrics.scatter_bytes);
        if cfg.stream_reduce {
            // incremental reducer: merge-join folds, O(|V|) each — never a
            // full re-sort of the running union
            assert!(bip.metrics.reduce_folds > 0);
            assert!(
                bip.metrics.reduce_fold_edges
                    <= bip.metrics.reduce_folds as u64 * 2 * (n as u64 - 1)
            );
        }
    });
}

#[test]
fn prop_affinity_decks_claim_every_job_exactly_once() {
    // JobQueue-level invariant: under concurrent stealing from per-worker
    // affinity decks, every pair job is claimed exactly once, and jobs only
    // count as stolen when popped off a foreign deck.
    use demst::exec::{ExecPlan, JobQueue};
    use std::sync::Mutex;

    Runner::new("affinity queue exactly-once", 0xAB, 20).run(|g| {
        let n = g.usize_in(12..80);
        let d = g.usize_in(1..5);
        let ds = int_points(g, n, d);
        let parts = g.usize_in(2..9).min(n / 2);
        let strategy = match g.usize_in(0..4) {
            0 => PartitionStrategy::Block,
            1 => PartitionStrategy::RoundRobin,
            2 => PartitionStrategy::RandomShuffle,
            _ => PartitionStrategy::KMeansLite,
        };
        let plan = ExecPlan::new(&ds, parts, strategy, g.rng().next_u64());
        let n_workers = g.usize_in(1..7);
        let aff = plan.affinity(n_workers);
        let queue = JobQueue::with_decks(aff.decks.clone());
        assert_eq!(queue.len(), plan.n_jobs());
        let claimed: Mutex<Vec<(usize, usize, bool)>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            let queue = &queue;
            let claimed = &claimed;
            for w in 0..n_workers {
                scope.spawn(move || {
                    let mut local = Vec::new();
                    while let Some((job, stolen)) = queue.pop_for(w) {
                        local.push((w, job, stolen));
                    }
                    claimed.lock().unwrap().extend(local);
                });
            }
        });
        let got = claimed.into_inner().unwrap();
        assert_eq!(got.len(), plan.n_jobs(), "every job claimed");
        let mut seen = vec![false; plan.n_jobs()];
        for &(w, job, stolen) in &got {
            assert!(!seen[job], "job {job} claimed twice");
            seen[job] = true;
            let on_own_deck = aff.decks[w % aff.decks.len()].contains(&job);
            if !stolen {
                assert!(on_own_deck, "worker {w} popped job {job} unstolen off a foreign deck");
            }
        }
        assert!(seen.iter().all(|&s| s));
    });
}

#[test]
fn prop_affinity_scatter_never_exceeds_dense_model() {
    // Engine-level invariant, any seed/parts/workers/kernel: total charged
    // scatter under affinity routing is ≤ the dense model, the saved
    // counter accounts for the difference exactly, and for parts ≥ 4 with
    // few workers the saving is strict (pigeonhole: some worker must run
    // two jobs sharing a subset).
    use demst::config::{KernelChoice, PairKernelChoice, RunConfig};
    use demst::coordinator::run_distributed;

    Runner::new("affinity scatter bound", 0xAC, 10).run(|g| {
        let n = g.usize_in(16..64);
        let d = g.usize_in(1..6);
        let ds = int_points(g, n, d);
        let parts = g.usize_in(4..8).min(n / 4);
        let strict = parts >= 4 && g.bool_p(0.5);
        let workers = if strict { g.usize_in(1..3) } else { g.usize_in(1..6) };
        let mut cfg = RunConfig {
            parts,
            workers,
            seed: g.rng().next_u64(),
            kernel: KernelChoice::PrimDense,
            pair_kernel: if g.bool_p(0.5) {
                PairKernelChoice::BipartiteMerge
            } else {
                PairKernelChoice::Dense
            },
            ..Default::default()
        };
        cfg.affinity = false;
        let dense = run_distributed(&ds, &cfg).unwrap();
        cfg.affinity = true;
        let aff = run_distributed(&ds, &cfg).unwrap();
        assert_eq!(normalize_tree(&dense.mst), normalize_tree(&aff.mst));
        assert!(
            aff.metrics.scatter_bytes <= dense.metrics.scatter_bytes,
            "affinity {} > dense {} (parts={parts} workers={workers})",
            aff.metrics.scatter_bytes,
            dense.metrics.scatter_bytes
        );
        assert_eq!(
            aff.metrics.scatter_bytes + aff.metrics.scatter_saved_bytes,
            dense.metrics.scatter_bytes,
            "saved counter must reconcile the models (parts={parts} workers={workers})"
        );
        if strict {
            assert!(
                aff.metrics.scatter_bytes < dense.metrics.scatter_bytes,
                "parts={parts} workers={workers}: saving must be strict"
            );
        }
    });
}

#[test]
fn prop_simd_panels_bit_identical_to_scalar() {
    // The tentpole contract of the SIMD kernel layer: for every metric, the
    // dispatched panel path (whatever ISA the host detects), the forced-
    // scalar panel path, and the per-row reference produce THE SAME BITS —
    // across remainder dimensions (d % 8 ≠ 0), panel sizes that straddle
    // the register tile, and every thread fan-out. Anything else would
    // change the strict (w, u, v) edge order downstream.
    use demst::geometry::simd::{self, PanelSettings};
    use demst::geometry::{distance_block_with, MetricKind};

    // tile edges for the 8×(4×2) register tile, plus off-by-ones
    const SIZES: [usize; 5] = [1, 3, 4, 5, 11];
    const DIMS: [usize; 8] = [1, 7, 8, 9, 16, 17, 19, 33];
    const THREADS: [usize; 3] = [1, 2, 4];

    Runner::new("simd panel bit-identity", 0xD6, 30).run(|g| {
        let d = DIMS[g.usize_in(0..DIMS.len())];
        let m = SIZES[g.usize_in(0..SIZES.len())];
        let n = SIZES[g.usize_in(0..SIZES.len())];
        let a: Vec<f32> = g.vec_f32(-8.0, 8.0, m * d);
        let b: Vec<f32> = g.vec_f32(-8.0, 8.0, n * d);
        let (pa, stride) = simd::pad_rows(&a, m, d);
        let (pb, _) = simd::pad_rows(&b, n, d);
        for kind in [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ] {
            // row-path reference over the stacked (m + n, d) matrix
            let reference = distance_block_with(kind, PanelSettings::scalar());
            let mut stacked = a.clone();
            stacked.extend_from_slice(&b);
            let aux = reference.prepare(&stacked, m + n, d);
            let js: Vec<u32> = (m as u32..(m + n) as u32).collect();
            let mut rows = vec![0.0f32; m * n];
            for i in 0..m {
                reference.row(&stacked, d, &aux, i, &js, &mut rows[i * n..(i + 1) * n]);
            }
            // per-panel aux (row-local norms; empty for manhattan)
            let aux_a = reference.prepare(&a, m, d);
            let aux_b = reference.prepare(&b, n, d);
            let mut scalar_out = vec![0.0f32; m * n];
            reference.panel_block(&pa, &aux_a, m, &pb, &aux_b, n, d, stride, &mut scalar_out);
            assert_eq!(
                scalar_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                rows.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{kind:?} d={d} m={m} n={n}: scalar panel vs rows"
            );
            for threads in THREADS {
                let settings = PanelSettings { threads, ..PanelSettings::detect() };
                let blk = distance_block_with(kind, settings);
                let mut out = vec![0.0f32; m * n];
                blk.panel_block(&pa, &aux_a, m, &pb, &aux_b, n, d, stride, &mut out);
                assert_eq!(
                    out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    scalar_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{kind:?} d={d} m={m} n={n} threads={threads} isa={}: SIMD vs scalar",
                    settings.isa.label()
                );
            }
        }
    });
}

#[test]
fn prop_wire_encoding_roundtrips_bit_identically() {
    // The net/wire codec is the single source of truth for Message sizes:
    // for every variant, `encode(m).len() == m.wire_bytes()` and
    // `decode(encode(m)) == m` bit-for-bit — which is what makes the
    // simulated byte charges equal the TCP transport's measured frames.
    use demst::coordinator::messages::{Message, SubsetShip};
    use demst::decomp::PairJob;
    use demst::net::wire::{self, WireCtx};
    use std::time::Duration;

    fn check(msg: &Message, ctx: Option<&WireCtx>) {
        let frame = wire::encode(msg).unwrap();
        assert_eq!(
            frame.len() as u64,
            msg.wire_bytes(),
            "encoded length != wire_bytes for {msg:?}"
        );
        assert_eq!(&wire::decode(&frame, ctx).unwrap(), msg, "decode(encode) drifted");
    }

    Runner::new("wire roundtrip", 0xAD, 40).run(|g| {
        let parts = g.usize_in(2..6);
        let d = g.usize_in(1..9);
        let part_sizes: Vec<u32> = (0..parts).map(|_| g.usize_in(1..9) as u32).collect();
        let ctx = WireCtx { d, part_sizes: part_sizes.clone() };
        // 48-bit wire durations; full-u64 nanos for WorkerDone busy
        let dur48 = Duration::from_nanos(g.rng().next_u64() & ((1 << 48) - 1));
        let busy = Duration::from_nanos(g.rng().next_u64() >> 1);

        let n_ids = g.usize_in(1..12);
        let points = Dataset::new(n_ids, d, g.vec_f32(-1e3, 1e3, n_ids * d));
        let global_ids: Vec<u32> = (0..n_ids as u32).map(|k| k * 3 + 1).collect();
        let job = PairJob {
            id: g.rng().next_u64() as u32,
            i: g.usize_in(0..parts) as u32,
            j: g.usize_in(0..parts) as u32,
        };
        check(
            &Message::Job { job, global_ids: global_ids.clone(), points: points.clone() },
            None,
        );
        check(
            &Message::LocalJob { part: g.usize_in(0..parts) as u32, global_ids, points },
            None,
        );

        // PairAssign: section lengths are derived from the handshake layout,
        // so vectors/trees must carry exactly |S_k| rows / |S_k|-1 edges.
        let (i, j) = {
            let a = g.usize_in(0..parts - 1);
            (a as u32, g.usize_in(a + 1..parts) as u32)
        };
        let mut ships = Vec::new();
        for part in [i, j] {
            let size = part_sizes[part as usize] as usize;
            let vectors = g.bool_p(0.6).then(|| {
                (
                    (0..size as u32).map(|k| k * 7 + part).collect::<Vec<u32>>(),
                    Dataset::new(size, d, g.vec_f32(-10.0, 10.0, size * d)),
                )
            });
            // a peer-routed section never carries its tree inline (wire
            // invariant); an unrouted one must ship *something*
            let routed = g.bool_p(0.3);
            let tree = (!routed && (g.bool_p(0.5) || vectors.is_none())).then(|| {
                (0..size.saturating_sub(1))
                    .map(|k| Edge::new(2 * k as u32, 2 * k as u32 + 1, g.f32_in(0.0, 9.0)))
                    .collect::<Vec<Edge>>()
            });
            if g.bool_p(0.75) {
                ships.push(SubsetShip { part, vectors, tree, routed });
            }
        }
        check(
            &Message::PairAssign { job: PairJob { id: 7, i, j }, ships },
            Some(&ctx),
        );

        let n_edges = g.usize_in(0..10);
        let edges: Vec<Edge> = (0..n_edges)
            .map(|k| Edge::new(2 * k as u32, 2 * k as u32 + 1, g.f32_in(-3.0, 50.0)))
            .collect();
        check(
            &Message::LocalDone {
                part: g.usize_in(0..parts) as u32,
                edges: edges.clone(),
                compute: dur48,
            },
            None,
        );
        check(
            &Message::Result {
                job_id: g.rng().next_u64() as u32,
                worker: g.usize_in(0..256),
                edges: edges.clone(),
                compute: dur48,
            },
            None,
        );
        check(&Message::Ack { job_id: g.rng().next_u64() as u32 }, None);
        check(&Message::LocalAssign { part: g.usize_in(0..parts) as u32 }, None);
        check(
            &Message::WorkerDone {
                worker: g.usize_in(0..65536),
                local_tree: g.bool_p(0.5).then_some(edges),
                dist_evals: g.rng().next_u64(),
                busy,
                jobs_run: g.rng().next_u64() as u32,
                jobs_stolen: g.rng().next_u64() as u32,
                panel_hits: g.rng().next_u64(),
                panel_misses: g.rng().next_u64(),
                panel_flops: g.rng().next_u64(),
                panel_time: Duration::from_nanos(g.rng().next_u64() >> 1),
                panel_threads: g.rng().next_u64() as u32,
                panel_isa: (g.rng().next_u64() % 4) as u8,
                peer_tx_bytes: g.rng().next_u64(),
                peer_ships: g.rng().next_u64() as u32,
                // wire v6: span block between the stats block and the tree —
                // random kinds (known and unknown codes pass through opaque),
                // random payloads, bit-identical after the roundtrip
                spans: (0..g.usize_in(0..20))
                    .map(|_| demst::obs::Span {
                        kind_code: (g.rng().next_u64() % 256) as u8,
                        worker: g.usize_in(0..65536) as u16,
                        id: g.rng().next_u64() as u32,
                        arg: g.rng().next_u64(),
                        start_ns: g.rng().next_u64(),
                        end_ns: g.rng().next_u64(),
                    })
                    .collect(),
                now_ns: g.rng().next_u64(),
                chaos_faults: g.rng().next_u64() as u32,
            },
            None,
        );
        check(&Message::Shutdown, None);

        // v4 peer data plane + fold orchestration frames
        use demst::coordinator::messages::PeerAddr;
        check(&Message::PairFail { job_id: g.rng().next_u64() as u32 }, None);
        check(&Message::FoldDone { ok: g.bool_p(0.5) }, None);
        check(&Message::PeerHello { from: g.usize_in(0..65536) as u16 }, None);
        check(&Message::TreeFetch { part: g.usize_in(0..parts) as u32 }, None);
        check(
            &Message::TreeShip {
                part: g.usize_in(0..parts) as u32,
                fold: g.bool_p(0.5),
                edges: (0..g.usize_in(0..12))
                    .map(|k| Edge::new(2 * k as u32, 2 * k as u32 + 1, g.f32_in(0.0, 40.0)))
                    .collect(),
            },
            None,
        );
        check(
            &Message::FoldShip {
                to: g.usize_in(0..65536) as u16,
                expect: g.usize_in(0..65536) as u16,
            },
            None,
        );
        let peers: Vec<PeerAddr> = (0..g.usize_in(0..6))
            .map(|k| {
                let ip = if g.bool_p(0.5) {
                    std::net::IpAddr::from([127, 0, 0, 1 + k as u8])
                } else {
                    std::net::IpAddr::from([0, 0, 0, 0, 0, 0, 0, 1 + k as u16])
                };
                PeerAddr { ip, port: g.usize_in(1..65536) as u16 }
            })
            .collect();
        let builders: Vec<u16> =
            (0..parts).map(|_| g.usize_in(0..65536) as u16).collect();
        check(&Message::PeerBook { peers, builders }, None);
    });
}

#[test]
fn prop_wire_decoders_survive_hostile_bytes() {
    // Robustness contract of every frame decoder: arbitrary garbage and
    // bit-flipped-but-plausible frames produce a clean `Err` (or a benign
    // reinterpretation) — never a panic, never an allocation driven by an
    // attacker-controlled length field. The leader runs these decoders
    // against bytes from the open admission listener, so "malformed input
    // is an error, not a crash" is a liveness property of the whole fleet.
    use demst::coordinator::messages::Message;
    use demst::net::wire::{
        self, AdmitAck, Hello, Join, Setup, SetupAck, ShardAdvertise, WireCtx, WIRE_VERSION,
    };

    // every decoder the transport feeds raw frames into
    fn poke_all(frame: &[u8], ctx: &WireCtx) {
        let _ = wire::decode(frame, Some(ctx));
        let _ = wire::decode(frame, None);
        let _ = wire::decode_hello(frame);
        let _ = wire::decode_setup(frame);
        let _ = wire::decode_setup_ack(frame);
        let _ = wire::decode_join(frame);
        let _ = wire::decode_admit_ack(frame);
        let _ = wire::decode_shard_advertise(frame);
    }

    Runner::new("wire hostile bytes", 0xB7, 80).run(|g| {
        let parts = g.usize_in(2..5);
        let ctx = WireCtx {
            d: g.usize_in(1..6),
            part_sizes: (0..parts).map(|_| g.usize_in(1..6) as u32).collect(),
        };

        // 1) pure garbage, every length from empty to past the header
        let len = g.usize_in(0..64);
        let garbage: Vec<u8> = (0..len).map(|_| g.rng().next_u64() as u8).collect();
        poke_all(&garbage, &ctx);

        // 2) a valid frame with one random bit flipped — tag, length
        //    fields, and payload corruption all land here eventually
        let job_id = g.rng().next_u64() as u32;
        let edges: Vec<Edge> = (0..g.usize_in(0..6))
            .map(|k| Edge::new(2 * k as u32, 2 * k as u32 + 1, g.f32_in(0.0, 9.0)))
            .collect();
        let setup = Setup {
            version: WIRE_VERSION,
            worker_id: g.usize_in(0..8) as u16,
            n: g.usize_in(1..100) as u32,
            d: ctx.d as u16,
            metric: 0,
            kernel: 0,
            pair_kernel: 0,
            reduce_tree: g.bool_p(0.5),
            mid_run: g.bool_p(0.5),
            trace: g.bool_p(0.5),
            manifest: g.rng().next_u64(),
            liveness_ms: g.rng().next_u64() as u32,
            part_sizes: ctx.part_sizes.clone(),
            artifacts_dir: "artifacts".into(),
        };
        let frames: Vec<Vec<u8>> = vec![
            wire::encode(&Message::Ack { job_id }).unwrap(),
            wire::encode(&Message::Heartbeat).unwrap(),
            wire::encode(&Message::Result {
                job_id,
                worker: 0,
                edges: edges.clone(),
                compute: std::time::Duration::from_micros(7),
            })
            .unwrap(),
            wire::encode(&Message::TreeShip { part: 1, fold: false, edges }).unwrap(),
            wire::encode_hello(&Hello { version: WIRE_VERSION, peer_port: 9 }),
            wire::encode_setup(&setup).unwrap(),
            wire::encode_setup_ack(&SetupAck { worker_id: 3 }),
            wire::encode_join(&Join { worker_id: 3, version: WIRE_VERSION }),
            wire::encode_admit_ack(&AdmitAck { worker_id: 3 }),
            wire::encode_shard_advertise(&ShardAdvertise {
                worker_id: 3,
                shard_ids: vec![0, 2],
            })
            .unwrap(),
        ];
        for frame in &frames {
            let mut bent = frame.clone();
            let bit = g.usize_in(0..bent.len() * 8);
            bent[bit / 8] ^= 1 << (bit % 8);
            poke_all(&bent, &ctx);
            // truncation at any point must also fail cleanly
            let cut = g.usize_in(0..frame.len());
            poke_all(&frame[..cut], &ctx);
        }

        // 3) a forged length prefix must be rejected by the frame reader
        //    BEFORE any allocation: cap-exceeding lengths are an error
        let mut forged = wire::encode(&Message::Ack { job_id }).unwrap();
        forged[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = wire::read_frame_io(&mut std::io::Cursor::new(&forged)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        // the handshake path is capped far tighter still
        let cap = wire::read_frame_capped_io(
            &mut std::io::Cursor::new(&forged),
            wire::MAX_HANDSHAKE_PAYLOAD,
        )
        .unwrap_err();
        assert_eq!(cap.kind(), std::io::ErrorKind::InvalidData, "{cap}");
    });
}

#[test]
fn prop_union_find_laws() {
    Runner::new("union-find", 0xA5, 50).run(|g| {
        let n = g.usize_in(1..200);
        let mut uf = UnionFind::new(n);
        let mut naive: Vec<usize> = (0..n).collect(); // naive labels
        for _ in 0..g.usize_in(0..300) {
            let a = g.usize_in(0..n) as u32;
            let b = g.usize_in(0..n) as u32;
            let merged = uf.union(a, b);
            let (la, lb) = (naive[a as usize], naive[b as usize]);
            assert_eq!(merged, la != lb);
            if la != lb {
                for l in naive.iter_mut() {
                    if *l == lb {
                        *l = la;
                    }
                }
            }
        }
        // same-set relation matches the naive model
        for _ in 0..50 {
            let a = g.usize_in(0..n) as u32;
            let b = g.usize_in(0..n) as u32;
            assert_eq!(uf.same(a, b), naive[a as usize] == naive[b as usize]);
        }
        let distinct: std::collections::HashSet<usize> = naive.iter().copied().collect();
        assert_eq!(uf.components(), distinct.len());
    });
}

#[test]
fn prop_dendrogram_laws() {
    Runner::new("dendrogram", 0xA6, 25).run(|g| {
        let n = g.usize_in(2..60);
        let d = g.usize_in(1..5);
        let ds = int_points(g, n, d);
        let mst = PrimDense::sq_euclid().mst(&ds);
        let dendro = mst_to_dendrogram(n, &mst);
        // heights non-decreasing
        let h = dendro.heights();
        assert!(h.windows(2).all(|w| w[0] <= w[1]), "monotone heights");
        // heights are exactly the MST weights (sorted)
        let mut ws: Vec<f32> = mst.iter().map(|e| e.w).collect();
        ws.sort_by(f32::total_cmp);
        assert_eq!(h, ws);
        // cut_to_k produces exactly k clusters for all k ≤ n
        for k in [1usize, 2, n / 2, n] {
            let k = k.max(1);
            let labels = dendro.cut_to_k(k);
            let distinct: std::collections::HashSet<u32> = labels.iter().copied().collect();
            assert_eq!(distinct.len(), k.min(n), "k={k}");
        }
        // cophenetic distance is an ultrametric: d(i,k) <= max(d(i,j), d(j,k))
        for _ in 0..20 {
            let i = g.usize_in(0..n) as u32;
            let j = g.usize_in(0..n) as u32;
            let k = g.usize_in(0..n) as u32;
            let dij = dendro.cophenetic(i, j);
            let djk = dendro.cophenetic(j, k);
            let dik = dendro.cophenetic(i, k);
            assert!(
                dik <= dij.max(djk) + 1e-5,
                "ultrametric violated: d({i},{k})={dik} > max({dij},{djk})"
            );
        }
        // round-trip preserves heights
        let back = mst_to_dendrogram(n, &dendro.to_mst());
        assert_eq!(back.heights(), dendro.heights());
    });
}

#[test]
fn prop_npy_roundtrip() {
    Runner::new("npy", 0xA7, 20).run(|g| {
        let n = g.usize_in(1..50);
        let d = g.usize_in(1..20);
        let data = g.vec_f32(-1e6, 1e6, n * d);
        let ds = Dataset::new(n, d, data);
        let dir = std::env::temp_dir().join("demst_prop_npy");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("t{}.npy", g.rng().next_u64()));
        demst::data::npy::write_npy(&path, &ds).unwrap();
        let back = demst::data::npy::read_npy(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(ds, back);
    });
}

#[test]
fn prop_shard_manifest_and_files_roundtrip_bit_identically() {
    // The sharded-residency contract: for arbitrary partitions, metrics,
    // and dimensions within wire limits, `demst partition`'s output —
    // manifest (layout as compact ranges, per-shard digests, fingerprint)
    // plus binary shard files — reloads bit-identically: same layout, same
    // digests, and every shard's id map and vector rows equal to the
    // gather from the original matrix. Flipping any payload byte is caught
    // by the checksum.
    use demst::decomp::PartitionStrategy;
    use demst::geometry::MetricKind;
    use demst::shard;

    Runner::new("shard roundtrip", 0xB1, 15).run(|g| {
        let n = g.usize_in(4..80);
        let d = g.usize_in(1..12);
        let parts = g.usize_in(2..6).min(n);
        let metric = [
            MetricKind::SqEuclid,
            MetricKind::Euclid,
            MetricKind::Cosine,
            MetricKind::Manhattan,
        ][g.usize_in(0..4)];
        let strategy = PartitionStrategy::ALL[g.usize_in(0..PartitionStrategy::ALL.len())];
        let seed = g.rng().next_u64();
        let ds = Dataset::new(n, d, g.vec_f32(-1e3, 1e3, n * d));
        let dir = std::env::temp_dir()
            .join("demst_prop_shard")
            .join(format!("case{}", g.rng().next_u64()));
        let (manifest, path) =
            shard::write_dataset_shards(&dir, "p", &ds, parts, strategy, seed, metric).unwrap();

        let loaded = shard::Manifest::load(&path).unwrap();
        assert_eq!(loaded.fingerprint(), manifest.fingerprint());
        assert_eq!(
            loaded.layout(),
            demst::decomp::partition_indices(&ds, parts, strategy, seed),
            "manifest layout == the partitioner's output"
        );
        assert_eq!(loaded.metric, metric);
        assert_eq!((loaded.n, loaded.d), (n, d));

        let shards = shard::load_worker_shards(&loaded, &(0..parts as u32).collect::<Vec<_>>())
            .unwrap();
        for s in &shards {
            assert_eq!(s.ids, loaded.shards[s.part as usize].ids);
            assert_eq!(s.points, ds.gather(&s.ids), "shard rows bit-identical to gather");
        }

        // corrupt one byte of one shard file: the digest check must fire
        let victim = loaded.shard_path(g.usize_in(0..parts));
        let mut bytes = std::fs::read(&victim).unwrap();
        let at = g.usize_in(0..bytes.len());
        bytes[at] ^= 0x20;
        std::fs::write(&victim, &bytes).unwrap();
        assert!(
            shard::load_worker_shards(&loaded, &(0..parts as u32).collect::<Vec<_>>()).is_err(),
            "flipped byte at {at} went undetected"
        );
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn prop_toml_numbers_roundtrip() {
    Runner::new("toml numbers", 0xA8, 30).run(|g| {
        let i = g.rng().next_u64() as i64 / 2;
        let doc = demst::config::parse_toml(&format!("x = {i}\n")).unwrap();
        assert_eq!(doc[""]["x"].as_int(), Some(i));
        let f = g.f32_in(-1e6, 1e6) as f64;
        let text = format!("y = {f:?}\n");
        let doc = demst::config::parse_toml(&text).unwrap();
        let got = doc[""]["y"].as_float().unwrap();
        assert!((got - f).abs() <= 1e-9 * (1.0 + f.abs()), "{f} -> {got}");
    });
}

#[test]
fn prop_knn_weight_dominates_exact() {
    // For any connected kNN result, weight(knn-MST) >= weight(exact MST);
    // equality iff the kNN graph contains the MST.
    Runner::new("knn dominance", 0xA9, 15).run(|g| {
        let n = g.usize_in(10..60);
        let d = g.usize_in(2..8);
        let ds = int_points(g, n, d);
        let k = g.usize_in(2..n - 1);
        let exact = demst::mst::total_weight(&PrimDense::sq_euclid().mst(&ds));
        let r = demst::baselines::knn_boruvka(&ds, k);
        if r.components == 1 {
            let w = demst::mst::total_weight(&r.forest);
            assert!(w >= exact - 1e-3, "knn={w} < exact={exact}");
        } else {
            assert!(r.forest.len() < n - 1);
        }
    });
}

#[test]
fn prop_recorded_spans_are_well_formed_per_thread() {
    // Recorder laws under random workloads: every guard-recorded interval
    // is monotonic (end >= start), intervals opened strictly inside another
    // on the same thread are properly nested (RAII guards cannot cross),
    // per-thread recording order preserves start-time order, and instants
    // are points. These are the invariants the Chrome-trace exporter leans
    // on to draw non-overlapping slices per track.
    use demst::obs::{self, SpanKind};

    Runner::new("span recorder laws", 0xC4, 12).run(|g| {
        let token = obs::begin_run();
        let threads = g.usize_in(1..4);
        let per_thread: Vec<usize> = (0..threads).map(|_| g.usize_in(1..12)).collect();
        let nest: Vec<bool> = (0..threads).map(|_| g.bool_p(0.5)).collect();
        std::thread::scope(|s| {
            for (w, (&jobs, &nested)) in per_thread.iter().zip(&nest).enumerate() {
                s.spawn(move || {
                    obs::adopt(token);
                    for j in 0..jobs {
                        let mut outer =
                            obs::span(SpanKind::Job, w as u16, (w * 100 + j) as u32);
                        outer.set_arg(j as u64);
                        if nested {
                            // a panel span strictly inside its job span
                            let _inner =
                                obs::span(SpanKind::Panel, w as u16, (w * 100 + j) as u32);
                        }
                        if j % 3 == 0 {
                            obs::instant(SpanKind::Chaos, w as u16, j as u32, 0);
                        }
                    }
                });
            }
        });
        let spans = obs::end_run(token);
        let expected: usize = per_thread
            .iter()
            .zip(&nest)
            .map(|(&jobs, &nested)| {
                jobs * (1 + usize::from(nested)) + jobs.div_ceil(3)
            })
            .sum();
        assert_eq!(spans.len(), expected, "every span recorded exactly once");
        for s in &spans {
            assert!(s.end_ns >= s.start_ns, "spans are monotonic");
            if s.kind().is_some_and(|k| k.is_instant()) {
                assert_eq!(s.start_ns, s.end_ns, "instants are points");
            }
        }
        for w in 0..threads as u16 {
            // Per-thread recording order: drop order means inner (Panel)
            // precedes outer (Job) in the buffer, but *within a kind* the
            // start times must ascend — the thread ran its jobs in order.
            for kind in [SpanKind::Job, SpanKind::Panel] {
                let starts: Vec<u64> = spans
                    .iter()
                    .filter(|s| s.worker == w && s.kind() == Some(kind))
                    .map(|s| s.start_ns)
                    .collect();
                assert!(
                    starts.windows(2).all(|p| p[0] <= p[1]),
                    "thread {w} {kind:?} spans start in order: {starts:?}"
                );
            }
            // Proper nesting: each job's panel span lies inside its job span.
            for s in spans.iter().filter(|s| s.worker == w) {
                if s.kind() == Some(SpanKind::Panel) {
                    let outer = spans
                        .iter()
                        .find(|o| {
                            o.worker == w && o.id == s.id && o.kind() == Some(SpanKind::Job)
                        })
                        .expect("every panel span has its enclosing job span");
                    assert!(
                        outer.start_ns <= s.start_ns && s.end_ns <= outer.end_ns,
                        "RAII guards nest properly"
                    );
                }
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Fleet metrics plane: the histogram algebra the leader's fleet merge rests
// on. Mergeability is the whole design — any worker's snapshot must be
// absorbable in any order, any grouping, without changing the answer.
// ---------------------------------------------------------------------------

/// u64 spread across all magnitudes (0, small, huge) so every bucket
/// regime — the linear low range and the log-linear tail — gets exercised.
fn wide_u64(g: &mut Gen) -> u64 {
    let shift = g.rng().next_bounded(64) as u32;
    g.rng().next_u64() >> shift
}

fn hist_of(values: &[u64]) -> demst::obs::metrics::HistSnap {
    use demst::obs::metrics::{Hist, Registry};
    let reg = Registry::new();
    for &v in values {
        reg.observe(Hist::JobLatency, v);
    }
    reg.snapshot().hist(Hist::JobLatency).clone()
}

#[test]
fn prop_histogram_merge_is_associative_and_commutative() {
    use demst::obs::metrics::HistSnap;
    Runner::new("hist merge laws", 0xC1, 40).run(|g| {
        let mut parts: Vec<Vec<u64>> = Vec::new();
        for _ in 0..3 {
            let len = g.usize_in(0..40);
            parts.push((0..len).map(|_| wide_u64(g)).collect());
        }
        let (a, b, c) = (hist_of(&parts[0]), hist_of(&parts[1]), hist_of(&parts[2]));
        // the empty histogram is the identity
        let mut e = HistSnap::default();
        e.merge(&a);
        assert_eq!(e, a);
        // commutative
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        // associative
        let mut ab_c = ab.clone();
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc);
    });
}

#[test]
fn prop_histogram_any_merge_tree_equals_one_registry() {
    Runner::new("hist merge tree", 0xC2, 30).run(|g| {
        let n = g.usize_in(1..120);
        let values: Vec<u64> = (0..n).map(|_| wide_u64(g)).collect();
        // deal the observations to k "workers", then fold their snapshots
        // in a random binary-tree order — the fleet merge, shuffled
        let k = g.usize_in(1..8);
        let mut shards: Vec<Vec<u64>> = vec![Vec::new(); k];
        for &v in &values {
            let s = g.usize_in(0..k);
            shards[s].push(v);
        }
        let mut snaps: Vec<_> = shards.iter().map(|s| hist_of(s)).collect();
        while snaps.len() > 1 {
            let picked = snaps.swap_remove(g.usize_in(0..snaps.len()));
            let j = g.usize_in(0..snaps.len());
            snaps[j].merge(&picked);
        }
        let whole = hist_of(&values);
        assert_eq!(snaps[0], whole, "merge tree must reproduce the single-registry histogram");
        assert_eq!(snaps[0].count, n as u64);
        assert_eq!(snaps[0].sum, values.iter().fold(0u64, |acc, &v| acc.wrapping_add(v)));
        assert_eq!(snaps[0].min, *values.iter().min().unwrap());
        assert_eq!(snaps[0].max, *values.iter().max().unwrap());
    });
}

#[test]
fn prop_histogram_quantiles_stay_within_their_bucket_bounds() {
    use demst::obs::metrics::{bucket_bounds, bucket_index};
    Runner::new("hist quantile bounds", 0xC3, 40).run(|g| {
        let n = g.usize_in(1..200);
        let mut values: Vec<u64> = (0..n).map(|_| wide_u64(g)).collect();
        let snap = hist_of(&values);
        values.sort_unstable();
        let mut prev = 0u64;
        for step in 0..=10 {
            let q = f64::from(step) / 10.0;
            let r = snap.quantile(q).expect("non-empty histogram always answers");
            // the exact order statistic the estimate stands in for
            let target = ((q * n as f64).ceil() as u64).clamp(1, n as u64);
            let t = values[(target - 1) as usize];
            let (lo, hi) = bucket_bounds(bucket_index(t));
            assert!(
                r >= lo && r <= hi,
                "q={q}: estimate {r} outside bucket [{lo}, {hi}) of true quantile {t}"
            );
            assert!(r >= snap.min && r <= snap.max, "estimate clamps into observed range");
            assert!(r >= prev, "quantiles are monotone in q: {r} < {prev}");
            prev = r;
        }
        assert_eq!(demst::obs::metrics::HistSnap::default().quantile(0.5), None);
    });
}

#[test]
fn prop_snapshot_merge_is_associative() {
    use demst::obs::metrics::{Ctr, Gauge, Registry, Snapshot};
    Runner::new("snapshot merge assoc", 0xC4, 25).run(|g| {
        let mut snaps: Vec<Snapshot> = Vec::new();
        for _ in 0..3 {
            let reg = Registry::new();
            for _ in 0..g.usize_in(0..20) {
                let (ns, i, j) = (wide_u64(g), g.rng().next_u32(), g.rng().next_u32());
                reg.observe_job(ns, i, j);
            }
            reg.add(Ctr::DistEvals, g.rng().next_bounded(1_000_000));
            reg.gauge_set(Gauge::QueueDepth, g.rng().next_bounded(100) as i64);
            snaps.push(reg.snapshot());
        }
        let mut left = snaps[0].clone();
        left.merge(&snaps[1]);
        left.merge(&snaps[2]);
        let mut bc = snaps[1].clone();
        bc.merge(&snaps[2]);
        let mut right = snaps[0].clone();
        right.merge(&bc);
        assert_eq!(left, right);
    });
}
