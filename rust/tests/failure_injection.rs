//! Failure injection: corrupted artifacts, missing files, bad manifests,
//! worker kernel-init failure — every failure must surface as a clear error,
//! never as a wrong tree.

use demst::config::{KernelChoice, RunConfig};
use demst::coordinator::run_distributed;
use demst::data::generators::uniform;
use demst::runtime::{Engine, Manifest};
use demst::util::prng::Pcg64;
use std::path::{Path, PathBuf};

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("demst_failures").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn corrupt_hlo_text_fails_to_parse_with_context() {
    let dir = tmpdir("corrupt");
    std::fs::write(dir.join("manifest.txt"), "cheapest_edge 64 8 bad.hlo.txt\n").unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO at all {{{").unwrap();
    let engine = Engine::load(&dir).unwrap();
    let bucket = engine.bucket_for("cheapest_edge", 10, 4).unwrap();
    let err = engine.executable(&bucket).err().expect("must fail").to_string();
    assert!(err.contains("bad.hlo.txt"), "error names the file: {err}");
}

#[test]
fn missing_artifact_file_fails_cleanly() {
    let dir = tmpdir("missing_file");
    std::fs::write(dir.join("manifest.txt"), "cheapest_edge 64 8 ghost.hlo.txt\n").unwrap();
    let engine = Engine::load(&dir).unwrap();
    let bucket = engine.bucket_for("cheapest_edge", 10, 4).unwrap();
    let err = engine.executable(&bucket).err().expect("must fail").to_string();
    assert!(err.contains("ghost.hlo.txt"), "{err}");
}

#[test]
fn missing_manifest_dir_fails_at_load() {
    let err = Engine::load(Path::new("/nonexistent/artifacts")).err().expect("must fail").to_string();
    assert!(err.contains("manifest"), "{err}");
    assert!(!Engine::artifacts_available(Path::new("/nonexistent/artifacts")));
}

#[test]
fn malformed_manifests_rejected() {
    for (name, text) in [
        ("wrong-arity", "cheapest_edge 64 8\n"),
        ("non-numeric", "cheapest_edge sixty 8 f.hlo.txt\n"),
        ("empty", "# nothing\n"),
    ] {
        let dir = tmpdir(name);
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        assert!(Manifest::load(&dir).is_err(), "{name} should fail");
    }
}

#[test]
fn worker_kernel_init_failure_surfaces_as_error_not_wrong_tree() {
    // XLA kernel pointed at a directory with a manifest whose buckets are
    // too small for the problem: workers fail to run jobs; the leader must
    // report the failure (job count mismatch), not return a partial tree.
    let dir = tmpdir("tiny_bucket");
    // valid manifest, but the only bucket (copied from real artifacts if
    // present) is too small for n=... — simpler: point at a manifest whose
    // file is missing so kernel init succeeds but execution fails.
    std::fs::write(dir.join("manifest.txt"), "cheapest_edge 8 4 ghost.hlo.txt\n").unwrap();
    let ds = uniform(64, 8, 1.0, Pcg64::seeded(1));
    let cfg = RunConfig {
        parts: 4,
        workers: 2,
        kernel: KernelChoice::BoruvkaXla,
        artifacts_dir: dir,
        ..Default::default()
    };
    // The worker thread panics (no fitting bucket) or errors; run_distributed
    // must return Err, never a silent wrong result.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_distributed(&ds, &cfg)
    }));
    match result {
        Ok(Ok(_)) => panic!("expected failure, got a tree"),
        Ok(Err(e)) => {
            let msg = e.to_string();
            assert!(
                msg.contains("job count mismatch") || msg.contains("hung up"),
                "unexpected error: {msg}"
            );
        }
        Err(_) => {} // worker panic propagated — also an acceptable loud failure
    }
}

#[test]
fn nonexistent_artifacts_dir_with_xla_kernel_errors() {
    let ds = uniform(32, 4, 1.0, Pcg64::seeded(2));
    let cfg = RunConfig {
        parts: 2,
        workers: 1,
        kernel: KernelChoice::BoruvkaXla,
        artifacts_dir: PathBuf::from("/definitely/not/here"),
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg);
    assert!(out.is_err(), "missing artifacts must error");
}

#[test]
fn truncated_npy_rejected() {
    let dir = tmpdir("npy");
    let path = dir.join("trunc.npy");
    // valid header claiming (100, 10) but no payload
    let body = "{'descr': '<f4', 'fortran_order': False, 'shape': (100, 10), }";
    let header = format!("{}\n", body);
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
    bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    bytes.extend_from_slice(&[0u8; 16]); // far too short
    std::fs::write(&path, bytes).unwrap();
    let err = demst::data::npy::read_npy(&path).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
}

#[test]
fn config_validation_rejects_bad_combinations() {
    // These mirror real misconfigurations a launcher must catch pre-flight.
    for (toml, needle) in [
        ("parts = 0", "parts"),
        ("[net]\nbandwidth = -1.0", "bandwidth"),
        ("kernel = \"xla\"\nmetric = \"manhattan\"", "Euclidean"),
        ("[data]\nn = 0", "positive"),
    ] {
        let err = RunConfig::from_toml(toml).unwrap_err().to_string();
        assert!(
            err.to_lowercase().contains(&needle.to_lowercase()),
            "{toml:?} -> {err}"
        );
    }
}
