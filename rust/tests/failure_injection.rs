//! Failure injection: corrupted artifacts, missing files, bad manifests,
//! worker kernel-init failure — every failure must surface as a clear error,
//! never as a wrong tree.
//!
//! Engine-level tests (PJRT compile/execute failure paths) only compile with
//! `--features backend-xla`; manifest, config, and npy failure paths run in
//! every build.

use demst::config::RunConfig;
use demst::runtime::Manifest;
use std::path::{Path, PathBuf};

#[cfg(feature = "backend-xla")]
use demst::config::KernelChoice;
#[cfg(feature = "backend-xla")]
use demst::coordinator::run_distributed;
#[cfg(feature = "backend-xla")]
use demst::data::generators::uniform;
#[cfg(feature = "backend-xla")]
use demst::runtime::Engine;
#[cfg(feature = "backend-xla")]
use demst::util::prng::Pcg64;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("demst_failures").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[cfg(feature = "backend-xla")]
#[test]
fn corrupt_hlo_text_fails_to_parse_with_context() {
    let dir = tmpdir("corrupt");
    std::fs::write(dir.join("manifest.txt"), "cheapest_edge 64 8 bad.hlo.txt\n").unwrap();
    std::fs::write(dir.join("bad.hlo.txt"), "this is not HLO at all {{{").unwrap();
    let engine = Engine::load(&dir).unwrap();
    let bucket = engine.bucket_for("cheapest_edge", 10, 4).unwrap();
    let err = engine.executable(&bucket).err().expect("must fail").to_string();
    assert!(err.contains("bad.hlo.txt"), "error names the file: {err}");
}

#[cfg(feature = "backend-xla")]
#[test]
fn missing_artifact_file_fails_cleanly() {
    let dir = tmpdir("missing_file");
    std::fs::write(dir.join("manifest.txt"), "cheapest_edge 64 8 ghost.hlo.txt\n").unwrap();
    let engine = Engine::load(&dir).unwrap();
    let bucket = engine.bucket_for("cheapest_edge", 10, 4).unwrap();
    let err = engine.executable(&bucket).err().expect("must fail").to_string();
    assert!(err.contains("ghost.hlo.txt"), "{err}");
}

#[cfg(feature = "backend-xla")]
#[test]
fn missing_manifest_dir_fails_at_load() {
    let err = Engine::load(Path::new("/nonexistent/artifacts")).err().expect("must fail").to_string();
    assert!(err.contains("manifest"), "{err}");
    assert!(!Engine::artifacts_available(Path::new("/nonexistent/artifacts")));
}

#[test]
fn missing_manifest_dir_fails_manifest_load() {
    // Feature-independent twin of the Engine-level check: the manifest layer
    // and the artifact probe work in every build.
    let err = Manifest::load(Path::new("/nonexistent/artifacts")).unwrap_err().to_string();
    assert!(err.contains("manifest"), "{err}");
    assert!(!demst::runtime::artifacts_available(Path::new("/nonexistent/artifacts")));
}

#[test]
fn malformed_manifests_rejected() {
    for (name, text) in [
        ("wrong-arity", "cheapest_edge 64 8\n"),
        ("non-numeric", "cheapest_edge sixty 8 f.hlo.txt\n"),
        ("empty", "# nothing\n"),
    ] {
        let dir = tmpdir(name);
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        assert!(Manifest::load(&dir).is_err(), "{name} should fail");
    }
}

#[cfg(feature = "backend-xla")]
#[test]
fn worker_kernel_init_failure_surfaces_as_error_not_wrong_tree() {
    // XLA kernel pointed at a directory with a manifest whose buckets are
    // too small for the problem: workers fail to run jobs; the leader must
    // report the failure (job count mismatch), not return a partial tree.
    let dir = tmpdir("tiny_bucket");
    // valid manifest, but the only bucket (copied from real artifacts if
    // present) is too small for n=... — simpler: point at a manifest whose
    // file is missing so kernel init succeeds but execution fails.
    std::fs::write(dir.join("manifest.txt"), "cheapest_edge 8 4 ghost.hlo.txt\n").unwrap();
    let ds = uniform(64, 8, 1.0, Pcg64::seeded(1));
    let cfg = RunConfig {
        parts: 4,
        workers: 2,
        kernel: KernelChoice::BoruvkaXla,
        artifacts_dir: dir,
        ..Default::default()
    };
    // The worker thread panics (no fitting bucket) or errors; run_distributed
    // must return Err, never a silent wrong result.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_distributed(&ds, &cfg)
    }));
    match result {
        Ok(Ok(_)) => panic!("expected failure, got a tree"),
        Ok(Err(e)) => {
            let msg = e.to_string();
            assert!(
                msg.contains("job count mismatch")
                    || msg.contains("hung up")
                    || msg.contains("distributed run failed"),
                "unexpected error: {msg}"
            );
        }
        Err(_) => {} // worker panic propagated — also an acceptable loud failure
    }
}

#[cfg(feature = "backend-xla")]
#[test]
fn nonexistent_artifacts_dir_with_xla_kernel_errors() {
    let ds = uniform(32, 4, 1.0, Pcg64::seeded(2));
    let cfg = RunConfig {
        parts: 2,
        workers: 1,
        kernel: KernelChoice::BoruvkaXla,
        artifacts_dir: PathBuf::from("/definitely/not/here"),
        ..Default::default()
    };
    let out = run_distributed(&ds, &cfg);
    assert!(out.is_err(), "missing artifacts must error");
}

#[cfg(not(feature = "backend-xla"))]
#[test]
fn xla_kernel_without_feature_falls_back_with_report() {
    // In a default (no-PJRT) build the same request degrades gracefully: the
    // blocked Rust provider runs and the metrics say why.
    use demst::config::KernelChoice;
    let ds = demst::data::generators::uniform(32, 4, 1.0, demst::util::prng::Pcg64::seeded(2));
    let cfg = RunConfig {
        parts: 2,
        workers: 1,
        kernel: KernelChoice::BoruvkaXla,
        artifacts_dir: PathBuf::from("/definitely/not/here"),
        ..Default::default()
    };
    let out = demst::coordinator::run_distributed(&ds, &cfg).expect("fallback must succeed");
    assert_eq!(out.mst.len(), ds.n - 1);
    assert_eq!(out.metrics.kernel, "boruvka-rust");
    let note = out.metrics.kernel_fallback.expect("fallback note");
    assert!(note.contains("backend-xla"), "{note}");
    assert!(out.metrics.summary().contains("fallback"), "{}", out.metrics.summary());
}

/// Elastic fleet under real process death: two spawned `demst worker`
/// processes on loopback, one rigged (chaos env hook) to exit abruptly —
/// no reply, no shutdown handshake, exactly like a SIGKILL — upon
/// receiving its third pair job. The run must complete on the survivor
/// with a bit-identical MST vs the sim transport, `worker_failures == 1`,
/// and `jobs_reassigned > 0`; the exactly-once claim/return lane
/// guarantees no job is recorded twice.
#[test]
fn killed_worker_mid_run_completes_with_bit_identical_tree() {
    use demst::config::{KernelChoice, PairKernelChoice, TransportChoice};
    use demst::coordinator::run_distributed;
    use demst::data::generators::uniform;
    use demst::mst::normalize_tree;
    use demst::net::launch;
    use demst::net::worker::CHAOS_EXIT_ENV;
    use demst::util::prng::Pcg64;
    use std::net::TcpListener;

    let ds = uniform(120, 6, 1.0, Pcg64::seeded(9100));
    for reduce_tree in [false, true] {
        let mut cfg = RunConfig {
            parts: 6, // 15 pair jobs: plenty left when the chaos worker dies
            workers: 2,
            kernel: KernelChoice::PrimDense,
            pair_kernel: PairKernelChoice::BipartiteMerge,
            reduce_tree,
            ..Default::default()
        };
        let sim = run_distributed(&ds, &cfg).unwrap();

        cfg.transport = TransportChoice::Tcp;
        cfg.listen = Some("127.0.0.1:0".into());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut healthy = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
            .args(["worker", "--connect", &addr])
            .spawn()
            .unwrap();
        let mut chaotic = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
            .args(["worker", "--connect", &addr])
            .env(CHAOS_EXIT_ENV, "2")
            .spawn()
            .unwrap();

        let run = launch::serve(&ds, &cfg, &listener)
            .unwrap_or_else(|e| panic!("reduce={reduce_tree}: run failed: {e:#}"));
        assert_eq!(
            normalize_tree(&sim.mst),
            normalize_tree(&run.mst),
            "reduce={reduce_tree}: tree must be bit-identical despite the mid-run death"
        );
        assert_eq!(run.metrics.worker_failures, 1, "reduce={reduce_tree}");
        assert!(
            run.metrics.jobs_reassigned > 0,
            "reduce={reduce_tree}: the dead worker's claimed jobs must be reassigned"
        );
        assert_eq!(run.metrics.jobs, 15, "reduce={reduce_tree}: every job recorded exactly once");

        let healthy_status = healthy.wait().unwrap();
        assert!(healthy_status.success(), "survivor must exit 0: {healthy_status}");
        let chaotic_status = chaotic.wait().unwrap();
        assert_eq!(chaotic_status.code(), Some(113), "chaos exit code");
    }
}

/// A worker killed **mid-ring-fold**: its pair jobs were acked and folded
/// into a partial MSF that dies with the process, before shipping anywhere.
/// The leader must roll every one of those jobs back into the exactly-once
/// lane, the survivors re-run them (⊕ idempotence makes re-folding safe),
/// and the final tree stays bit-identical — with `jobs_reassigned > 0`
/// witnessing the recovery.
#[test]
fn killed_worker_mid_ring_fold_recovers_bit_identically() {
    use demst::config::{KernelChoice, PairKernelChoice, ReduceTopology, TransportChoice};
    use demst::coordinator::run_distributed;
    use demst::data::generators::uniform;
    use demst::mst::normalize_tree;
    use demst::net::launch;
    use demst::net::worker::CHAOS_EXIT_ON_FOLD_ENV;
    use demst::util::prng::Pcg64;
    use std::net::TcpListener;

    let ds = uniform(130, 6, 1.0, Pcg64::seeded(9200));
    let mut cfg = RunConfig {
        parts: 6, // 15 pair jobs across 3 workers
        workers: 3,
        kernel: KernelChoice::PrimDense,
        pair_kernel: PairKernelChoice::BipartiteMerge,
        reduce_tree: true,
        reduce_topology: ReduceTopology::Ring,
        ..Default::default()
    };
    let sim = run_distributed(&ds, &cfg).unwrap();

    cfg.transport = TransportChoice::Tcp;
    cfg.listen = Some("127.0.0.1:0".into());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // Connect the chaotic worker first: accept order assigns worker ids, and
    // ring folds settle in ascending id order — killing worker 0 leaves the
    // still-unsettled survivors to absorb the returned jobs. (A kill at the
    // very last rendezvous has no fleet left to recover on by design.)
    let mut chaotic = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
        .args(["worker", "--connect", &addr])
        .env(CHAOS_EXIT_ON_FOLD_ENV, "1")
        .spawn()
        .unwrap();
    std::thread::sleep(std::time::Duration::from_millis(500));
    let healthy: Vec<_> = (0..2)
        .map(|_| {
            std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
                .args(["worker", "--connect", &addr])
                .spawn()
                .unwrap()
        })
        .collect();

    let run = launch::serve(&ds, &cfg, &listener)
        .unwrap_or_else(|e| panic!("mid-fold kill: run failed: {e:#}"));
    assert_eq!(
        normalize_tree(&sim.mst),
        normalize_tree(&run.mst),
        "tree must be bit-identical despite the mid-fold death"
    );
    assert_eq!(run.metrics.worker_failures, 1);
    assert!(
        run.metrics.jobs_reassigned > 0,
        "the dead worker's folded-but-unshipped jobs must be reassigned"
    );
    assert_eq!(run.metrics.jobs, 15, "every job recorded exactly once");
    assert_eq!(run.metrics.reduce_topology, "ring");

    for mut child in healthy {
        let status = child.wait().unwrap();
        assert!(status.success(), "survivor must exit 0: {status}");
    }
    assert_eq!(chaotic.wait().unwrap().code(), Some(114), "mid-fold chaos exit code");
}

/// Tentpole acceptance: a worker that **stalls** mid-run — chaos `stall`
/// fault, the process stays alive but never sends another frame, exactly
/// like a wedged NFS mount or a half-open socket — is detected within
/// `liveness_timeout` by the leader's per-link read deadline, its claimed
/// jobs fail over through the exactly-once return lane, and a replacement
/// `demst worker --connect` started *after* the run began is admitted via
/// the versioned `Join`/`AdmitAck` handshake and rebalanced onto. The
/// final tree must be bit-identical to the sim run: liveness + admission
/// are pure scheduling.
#[test]
fn stalled_worker_detected_and_replacement_admitted_mid_run() {
    use demst::config::{KernelChoice, TransportChoice};
    use demst::coordinator::run_distributed;
    use demst::data::generators::uniform;
    use demst::mst::normalize_tree;
    use demst::net::{chaos, launch};
    use demst::util::prng::Pcg64;
    use std::net::TcpListener;

    let ds = uniform(120, 6, 1.0, Pcg64::seeded(9300));
    let mut cfg = RunConfig {
        parts: 6, // 15 pair jobs: plenty outstanding when the stall trips
        workers: 2,
        kernel: KernelChoice::PrimDense,
        ..Default::default()
    };
    let sim = run_distributed(&ds, &cfg).unwrap();

    cfg.transport = TransportChoice::Tcp;
    cfg.listen = Some("127.0.0.1:0".into());
    // Short deadline so detection is fast under test, but still orders of
    // magnitude above a single n=120 pair job's compute time — the
    // deadline must only ever trip on the genuinely stalled link.
    cfg.net.liveness_timeout_ms = 1_500;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut healthy = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
        .args(["worker", "--connect", &addr])
        .spawn()
        .unwrap();
    // Worker tx frames: Hello(1), SetupAck(2), ShardAdvertise(3), then one
    // reply per job — tx6 wedges the worker just before its third reply,
    // mid-run with claimed jobs in its pipeline window. Only the leader's
    // deadline can see this: the socket stays open and the process alive.
    let mut stalled = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
        .args(["worker", "--connect", &addr])
        .env(chaos::PLAN_ENV, "tx6:stall")
        .spawn()
        .unwrap();
    // Admit a replacement mid-run: by 800 ms the startup handshake (exactly
    // two accepts) is long done, and the run is still in flight because the
    // leader's 1.5 s deadline on the stalled link has not tripped yet.
    let late_addr = addr.clone();
    let replacement = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(800));
        std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
            .args(["worker", "--connect", &late_addr])
            .spawn()
            .unwrap()
    });

    let run = launch::serve(&ds, &cfg, &listener)
        .unwrap_or_else(|e| panic!("stall + admission: run failed: {e:#}"));
    assert_eq!(
        normalize_tree(&sim.mst),
        normalize_tree(&run.mst),
        "tree must be bit-identical despite the stall and the mid-run admission"
    );
    assert!(run.metrics.stalls_detected >= 1, "the liveness deadline must classify the stall");
    assert!(run.metrics.worker_failures >= 1, "a stall is demoted like a failure");
    assert!(run.metrics.workers_admitted >= 1, "the late worker must be admitted mid-run");
    assert!(run.metrics.jobs_reassigned > 0, "the stalled worker's claimed jobs must fail over");
    assert_eq!(run.metrics.jobs, 15, "every job recorded exactly once");
    assert!(
        run.metrics.heartbeats_sent >= 1,
        "the ≥1.5 s stall window spans several 500 ms pulse ticks over idle links"
    );

    let mut replacement = replacement.join().unwrap();
    assert!(replacement.wait().unwrap().success(), "admitted worker must exit 0");
    assert!(healthy.wait().unwrap().success(), "survivor must exit 0");
    // The stall fault loops forever by design — reap the process ourselves.
    stalled.kill().unwrap();
    stalled.wait().unwrap();
}

/// PR-7 `PairFail` demotion exercised in-process: with peer routing on, the
/// executor's first cached-tree fetch is denied pre-dial
/// (`DEMST_CHAOS_PEER_DENY`), standing in for a builder that died between
/// planning and fetch. The worker must reply `PairFail` (the job never
/// ran), the leader must demote both parts to inline shipping and return
/// the job to the exactly-once lane — no worker is failed, and the re-plan
/// keeps the tree bit-identical.
#[test]
fn denied_peer_fetch_demotes_to_inline_shipping_and_replays_the_job() {
    use demst::config::{KernelChoice, PairKernelChoice, TransportChoice};
    use demst::coordinator::run_distributed;
    use demst::data::generators::uniform;
    use demst::mst::normalize_tree;
    use demst::net::{chaos, launch};
    use demst::util::prng::Pcg64;
    use std::net::TcpListener;

    let ds = uniform(120, 6, 1.0, Pcg64::seeded(9400));
    let mut cfg = RunConfig {
        parts: 6,
        workers: 2,
        kernel: KernelChoice::PrimDense,
        pair_kernel: PairKernelChoice::BipartiteMerge,
        peer_route: Some(true),
        ..Default::default()
    };
    let sim = run_distributed(&ds, &cfg).unwrap();

    cfg.transport = TransportChoice::Tcp;
    cfg.listen = Some("127.0.0.1:0".into());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut healthy = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
        .args(["worker", "--connect", &addr])
        .spawn()
        .unwrap();
    // Each worker builds 3 of the 6 local trees, so its deck is mostly
    // cross-builder pairs — the first routed fetch in this process is
    // denied before the dial, as if the building anchor had just died.
    let mut denied = std::process::Command::new(env!("CARGO_BIN_EXE_demst"))
        .args(["worker", "--connect", &addr])
        .env(chaos::PEER_DENY_ENV, "1")
        .spawn()
        .unwrap();

    let run = launch::serve(&ds, &cfg, &listener)
        .unwrap_or_else(|e| panic!("peer-deny: run failed: {e:#}"));
    assert_eq!(
        normalize_tree(&sim.mst),
        normalize_tree(&run.mst),
        "tree must be bit-identical despite the demoted route"
    );
    assert_eq!(run.metrics.jobs, 15, "every job recorded exactly once");
    assert!(
        run.metrics.jobs_reassigned >= 1,
        "the failed-fetch job must return to the lane for a tree-inline re-plan"
    );
    assert_eq!(run.metrics.worker_failures, 0, "a PairFail demotes the route, not the worker");
    assert_eq!(run.metrics.stalls_detected, 0);

    assert!(healthy.wait().unwrap().success(), "worker must exit 0");
    assert!(denied.wait().unwrap().success(), "the denied worker continues and exits 0");
}

#[test]
fn truncated_npy_rejected() {
    let dir = tmpdir("npy");
    let path = dir.join("trunc.npy");
    // valid header claiming (100, 10) but no payload
    let body = "{'descr': '<f4', 'fortran_order': False, 'shape': (100, 10), }";
    let header = format!("{}\n", body);
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
    bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    bytes.extend_from_slice(&[0u8; 16]); // far too short
    std::fs::write(&path, bytes).unwrap();
    let err = demst::data::npy::read_npy(&path).unwrap_err().to_string();
    assert!(err.contains("truncated"), "{err}");
}

#[test]
fn config_validation_rejects_bad_combinations() {
    // These mirror real misconfigurations a launcher must catch pre-flight.
    for (toml, needle) in [
        ("parts = 0", "parts"),
        ("[net]\nbandwidth = -1.0", "bandwidth"),
        ("kernel = \"xla\"\nmetric = \"manhattan\"", "Euclidean"),
        ("[data]\nn = 0", "positive"),
    ] {
        let err = RunConfig::from_toml(toml).unwrap_err().to_string();
        assert!(
            err.to_lowercase().contains(&needle.to_lowercase()),
            "{toml:?} -> {err}"
        );
    }
}
