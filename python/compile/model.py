"""L2: the jax compute graph the Rust coordinator drives.

For this paper the "model" is the dense-MST compute: the Borůvka
cheapest-edge step (the d-MST subkernel's O(N²D) hot-spot, calling the L1
Pallas kernel) and the pairwise block used by baselines/benches. Each
function here is a pure jax function lowered once per shape bucket by
``aot.py``; nothing in this package runs at serve time.

Outputs are tuples because the AOT path lowers with return_tuple=True and
the Rust side unwraps with to_tupleN (see /opt/xla-example/README.md).
"""

from .kernels import cheapest_edge as ce
from .kernels import pairwise as pw


def boruvka_step(points, comps):
    """One Borůvka round's cheapest-edge query.

    points: (N, D) f32, comps: (N,) i32 (−1 padding).
    Returns (dist (N,) f32, idx (N,) i32).
    """
    dist, idx = ce.cheapest_edge(points, comps)
    return dist, idx


def pairwise_matrix(points):
    """Full (N, N) squared-Euclidean distance matrix; 1-tuple output."""
    return (pw.pairwise(points),)
