"""Shape buckets shared between the AOT compiler and the Rust runtime.

Executables are compiled for fixed (N, D) buckets; the Rust runtime pads a
logical (n, d) problem up to the smallest fitting bucket (rows masked with
comp = -1, feature dims zero-padded — both distance-preserving).

Keep this list in sync with nothing: the Rust side discovers buckets from
artifacts/manifest.txt, which aot.py generates from these tables.
"""

# Row-capacity buckets (powers of two: Borůvka pads rows, and power-of-two
# tiles keep the Pallas grid even).
CHEAPEST_EDGE_NS = [64, 128, 256, 512, 1024, 2048]
# Feature-dim buckets: cover small synthetic dims through BERT-ish 768.
CHEAPEST_EDGE_DS = [8, 32, 128, 768]

# The pairwise kernel is used by benches/tests at smaller scale.
PAIRWISE_NS = [64, 256, 1024]
PAIRWISE_DS = [8, 32, 128, 768]

# Pallas block sizes (rows per tile), clamped to min(n, BLOCK) per call —
# every N bucket is a power of two ≥ 64, so the clamp always divides n.
#
# Perf note (EXPERIMENTS.md §Perf L1): 256×256 tiles raise the modeled TPU
# arithmetic intensity of the cheapest-edge step from ~32 flop/byte (64×64)
# to ~128 flop/byte (row tile resident per grid row; col tiles streamed:
# intensity ≈ ROW_BLOCK/2), past the MXU roofline knee for bf16/f32, while
# VMEM stays comfortable: at D=768, row tile + col tile (2×768 KiB) +
# 256×256 distance tile (256 KiB) + accumulators ≈ 1.8 MiB, double-buffered
# < 4 MiB of a 16 MiB VMEM. Also 16× fewer grid steps than 64×64.
ROW_BLOCK = 256
COL_BLOCK = 256


def cheapest_edge_buckets():
    return [(n, d) for n in CHEAPEST_EDGE_NS for d in CHEAPEST_EDGE_DS]


def pairwise_buckets():
    return [(n, d) for n in PAIRWISE_NS for d in PAIRWISE_DS]
