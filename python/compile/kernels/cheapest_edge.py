"""L1 Pallas kernel: the Borůvka cheapest-edge step — the paper's d-MST
compute hot-spot.

For points (N, D) and component labels comps (N,) int32 (−1 = padding), find
for every valid row the squared distance and index of the nearest vertex in a
*different* component. One call performs the full O(N²D) masked
nearest-other-component reduction; the Rust coordinator loops ≤ log₂N such
calls with a union-find between them.

Grid layout: (row_tiles, col_tiles). The col axis is a *reduction* axis —
the output BlockSpec maps every (i, j) step to row block i, and the kernel
accumulates a running (min, argmin) pair across j steps in the output refs
(init at j == 0). Strictly-less comparisons keep the smallest column index on
exact ties, matching the Rust providers' tie-break contract (which in turn
matches the crate's strict (w, u, v) edge order — see
`demst::dense::step`).

VMEM per step (f32): row tile bm·D + col tile bn·D + (bm, bn) distance tile
+ O(bm) accumulators — e.g. D=768, 64×64 tiles: ~0.5 MiB, comfortably inside
a 16 MiB VMEM with double buffering. The cross term is one MXU matmul.
interpret=True for CPU-PJRT executability (see pairwise.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import shapes


def _cheapest_edge_kernel(x_ref, cx_ref, y_ref, cy_ref, dist_ref, idx_ref, *, bn):
    j = pl.program_id(1)
    x = x_ref[...]      # (bm, d)  row tile
    cx = cx_ref[...]    # (bm,)
    y = y_ref[...]      # (bn, d)  col tile
    cy = cy_ref[...]    # (bn,)

    xx = jnp.sum(x * x, axis=1, keepdims=True)
    yy = jnp.sum(y * y, axis=1)[None, :]
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)                 # (bm, bn)

    valid = (cx[:, None] >= 0) & (cy[None, :] >= 0) & (cx[:, None] != cy[None, :])
    d2 = jnp.where(valid, d2, jnp.inf)

    local_idx = jnp.argmin(d2, axis=1)                        # first-min = smallest j
    local_min = jnp.min(d2, axis=1)
    global_idx = (j * bn + local_idx).astype(jnp.int32)
    global_idx = jnp.where(jnp.isinf(local_min), jnp.int32(-1), global_idx)

    @pl.when(j == 0)
    def _init():
        dist_ref[...] = local_min
        idx_ref[...] = global_idx

    @pl.when(j > 0)
    def _accum():
        prev = dist_ref[...]
        prev_idx = idx_ref[...]
        better = local_min < prev  # strict: earlier col tile wins ties
        dist_ref[...] = jnp.where(better, local_min, prev)
        idx_ref[...] = jnp.where(better, global_idx, prev_idx)


@functools.partial(jax.jit, static_argnames=("row_block", "col_block"))
def cheapest_edge(points, comps, *, row_block=None, col_block=None):
    """Masked nearest-other-component (dist, idx) per row.

    points: (n, d) f32; comps: (n,) i32, −1 marks padding.
    Returns (dist (n,) f32 with +inf for isolated/padded rows,
             idx (n,) i32 with −1 for isolated/padded rows).
    """
    n, d = points.shape
    assert comps.shape == (n,)
    bm = row_block or min(n, shapes.ROW_BLOCK)
    bn = col_block or min(n, shapes.COL_BLOCK)
    assert n % bm == 0 and n % bn == 0, f"n={n} not a multiple of blocks ({bm},{bn})"
    grid = (n // bm, n // bn)
    kernel = functools.partial(_cheapest_edge_kernel, bn=bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(points, comps, points, comps)
