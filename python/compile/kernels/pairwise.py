"""L1 Pallas kernel: tiled (N, N) squared-Euclidean distance matrix.

TPU-shaped structure (see DESIGN.md §Hardware-Adaptation): the grid tiles the
output matrix; each step keeps an (bm, d) row tile and (bn, d) column tile
resident in VMEM and computes the cross term with one MXU matmul
(`x @ y.T`), adding the row/col norms lane-wise on the VPU. interpret=True —
the CPU PJRT client cannot execute Mosaic custom-calls; on a real TPU the
same BlockSpecs lower to Mosaic unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import shapes


def _pairwise_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]  # (bm, d) row tile
    y = y_ref[...]  # (bn, d) col tile
    xx = jnp.sum(x * x, axis=1, keepdims=True)            # (bm, 1)
    yy = jnp.sum(y * y, axis=1)[None, :]                  # (1, bn)
    xy = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    # clamp catastrophic-cancellation negatives to 0
    o_ref[...] = jnp.maximum(xx + yy - 2.0 * xy, 0.0)


@functools.partial(jax.jit, static_argnames=("block",))
def pairwise(x, *, block=None):
    """Full (n, n) squared-Euclidean distance matrix of row-major points."""
    n, d = x.shape
    bm = block or min(n, shapes.ROW_BLOCK)
    assert n % bm == 0, f"n={n} must be a multiple of the block {bm}"
    grid = (n // bm, n // bm)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(x, x)
