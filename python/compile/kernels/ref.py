"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: simple, unfused, obviously-right
formulations that pytest compares the kernels against across a shape/dtype
sweep (and that the Rust side's blocked routines mirror).
"""

import jax.numpy as jnp


def pairwise_ref(x):
    """(n, d) -> (n, n) squared Euclidean distances, matmul form, clamped."""
    xx = jnp.sum(x * x, axis=1)
    d2 = xx[:, None] + xx[None, :] - 2.0 * (x @ x.T)
    return jnp.maximum(d2, 0.0)


def pairwise_direct_ref(x):
    """(n, d) -> (n, n) via explicit differences (no cancellation)."""
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def cheapest_edge_ref(points, comps):
    """Reference Borůvka cheapest-edge step.

    For each valid vertex i (comps[i] >= 0): the squared distance and index
    of the nearest j with comps[j] >= 0 and comps[j] != comps[i]; ties break
    to the smallest j (jnp.argmin convention). Invalid/isolated rows report
    (+inf, -1).
    """
    d2 = pairwise_ref(points)
    valid = (comps[None, :] >= 0) & (comps[:, None] >= 0) & (
        comps[None, :] != comps[:, None]
    )
    masked = jnp.where(valid, d2, jnp.inf)
    idx = jnp.argmin(masked, axis=1).astype(jnp.int32)
    dist = jnp.take_along_axis(masked, idx[:, None], axis=1)[:, 0]
    idx = jnp.where(jnp.isinf(dist), jnp.int32(-1), idx)
    return dist, idx
