"""L1 Pallas kernels (build-time only; lowered AOT to HLO text).

- ``pairwise``      — tiled (N, N) squared-Euclidean distance matrix
- ``cheapest_edge`` — Borůvka step: per-vertex nearest other-component vertex
- ``ref``           — pure-jnp oracles both kernels are tested against
"""

from . import cheapest_edge, pairwise, ref  # noqa: F401
