"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the CORE correctness signal for the compiled artifacts — the same
jitted functions tested here are what aot.py lowers to HLO text for the Rust
runtime. Hypothesis sweeps shapes, block sizes, component labelings, and
degenerate inputs.
"""

import numpy as np
import pytest

# The AOT layer is optional: offline environments without jax/Pallas (or the
# hypothesis dev-dependency) must SKIP this module, not fail collection —
# mirroring the off-by-default `backend-xla` feature on the Rust side.
jax = pytest.importorskip("jax", reason="jax/Pallas unavailable — AOT layer is optional")
hypothesis = pytest.importorskip("hypothesis", reason="hypothesis unavailable")

import hypothesis.strategies as st  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402,F401

from compile.kernels import cheapest_edge as ce
from compile.kernels import pairwise as pw
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("kernels")


def rand_points(seed, n, d, scale=4.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.random((n, d), dtype=np.float32) - 0.5) * 2 * scale)


# ---------------------------------------------------------------- pairwise


@pytest.mark.parametrize("n,d", [(64, 8), (64, 32), (128, 16), (256, 8), (64, 768)])
def test_pairwise_matches_ref(n, d):
    x = rand_points(n * 31 + d, n, d)
    got = pw.pairwise(x)
    want = ref.pairwise_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_pairwise_matches_direct_form():
    # matmul form vs explicit differences (loose tol: cancellation)
    x = rand_points(7, 64, 16)
    got = pw.pairwise(x)
    want = ref.pairwise_direct_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_pairwise_diagonal_and_symmetry():
    x = rand_points(3, 128, 32)
    m = np.asarray(pw.pairwise(x))
    assert np.all(np.abs(np.diag(m)) <= 1e-3)
    np.testing.assert_allclose(m, m.T, rtol=0, atol=1e-4)
    assert np.all(m >= 0.0), "clamped non-negative"


@given(st.sampled_from([64, 128, 256]), st.sampled_from([1, 3, 8, 33]))
def test_pairwise_shape_sweep(n, d):
    x = rand_points(n + d, n, d)
    got = pw.pairwise(x)
    assert got.shape == (n, n)
    np.testing.assert_allclose(got, ref.pairwise_ref(x), rtol=1e-5, atol=1e-4)


def test_pairwise_block_invariance():
    # different tilings must agree (same arithmetic per tile)
    x = rand_points(11, 128, 8)
    a = pw.pairwise(x, block=32)
    b = pw.pairwise(x, block=64)
    c = pw.pairwise(x, block=128)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-5)
    np.testing.assert_allclose(b, c, rtol=1e-6, atol=1e-5)


def test_pairwise_rejects_indivisible_block():
    x = rand_points(1, 100, 4)
    with pytest.raises(AssertionError):
        pw.pairwise(x, block=64)


# ------------------------------------------------------------ cheapest edge


def check_cheapest_edge(x, comps, **kw):
    got_d, got_i = ce.cheapest_edge(x, comps, **kw)
    want_d, want_i = ref.cheapest_edge_ref(x, comps)
    got_d, got_i = np.asarray(got_d), np.asarray(got_i)
    want_d, want_i = np.asarray(want_d), np.asarray(want_i)
    # indices must match exactly (tie-break contract)...
    np.testing.assert_array_equal(got_i, want_i)
    # ...distances to float tolerance, inf patterns exactly
    np.testing.assert_array_equal(np.isinf(got_d), np.isinf(want_d))
    fin = ~np.isinf(want_d)
    np.testing.assert_allclose(got_d[fin], want_d[fin], rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("n,d", [(64, 8), (128, 32), (256, 8), (64, 768)])
def test_cheapest_edge_matches_ref(n, d):
    x = rand_points(n * 7 + d, n, d)
    comps = jnp.asarray((np.arange(n) % 7).astype(np.int32))
    check_cheapest_edge(x, comps)


def test_cheapest_edge_with_padding_rows():
    n, d = 128, 16
    x = rand_points(5, n, d)
    comps = (np.arange(n) % 4).astype(np.int32)
    comps[10:30] = -1  # padding block
    comps[n - 1] = -1
    check_cheapest_edge(x, jnp.asarray(comps))
    # padded rows report (inf, -1) and are never selected
    got_d, got_i = ce.cheapest_edge(x, jnp.asarray(comps))
    got_d, got_i = np.asarray(got_d), np.asarray(got_i)
    assert np.all(np.isinf(got_d[10:30])) and np.all(got_i[10:30] == -1)
    valid = got_i >= 0
    assert np.all(~np.isin(got_i[valid], np.arange(10, 30)))


def test_cheapest_edge_single_component_isolated():
    n, d = 64, 4
    x = rand_points(9, n, d)
    comps = jnp.zeros((n,), jnp.int32)
    got_d, got_i = ce.cheapest_edge(x, comps)
    assert bool(jnp.all(jnp.isinf(got_d)))
    assert bool(jnp.all(got_i == -1))


def test_cheapest_edge_two_singletons_point_at_each_other():
    n, d = 64, 2
    x = np.zeros((n, d), np.float32)
    x[0] = [0.0, 0.0]
    x[1] = [3.0, 4.0]
    comps = np.full((n,), -1, np.int32)
    comps[0], comps[1] = 0, 1
    got_d, got_i = ce.cheapest_edge(jnp.asarray(x), jnp.asarray(comps))
    assert float(got_d[0]) == pytest.approx(25.0)
    assert int(got_i[0]) == 1 and int(got_i[1]) == 0


def test_cheapest_edge_tie_breaks_to_smallest_index():
    # vertices 1 and 2 exactly equidistant from 0; cross-tile tie too
    n, d = 128, 2
    x = np.zeros((n, d), np.float32)
    x[1] = [1.0, 0.0]
    x[2] = [0.0, 1.0]
    x[64] = [1.0, 0.0]  # exact duplicate of x[1] in the second col tile
    comps = np.full((n,), -1, np.int32)
    comps[0], comps[1], comps[2], comps[64] = 0, 1, 1, 1
    _, got_i = ce.cheapest_edge(jnp.asarray(x), jnp.asarray(comps))
    assert int(got_i[0]) == 1, "smallest index wins the tie, within and across tiles"


@given(
    st.sampled_from([64, 128]),
    st.sampled_from([2, 5, 17]),
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_cheapest_edge_hypothesis_sweep(n, d, ncomp, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    comps = rng.integers(-1, ncomp, size=n).astype(np.int32)
    check_cheapest_edge(x, jnp.asarray(comps))


def test_cheapest_edge_block_invariance():
    n, d = 128, 8
    x = rand_points(21, n, d)
    comps = jnp.asarray((np.arange(n) % 3).astype(np.int32))
    a = ce.cheapest_edge(x, comps, row_block=32, col_block=32)
    b = ce.cheapest_edge(x, comps, row_block=64, col_block=128)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-6, atol=1e-5)
