"""L2/AOT: model functions lower to HLO text, shapes are right, and the
lowered computation computes the same thing the eager path does.
"""

import pathlib
import tempfile

import numpy as np
import pytest

# Optional AOT layer: skip (not fail) when jax/Pallas is unavailable, like
# the `backend-xla` feature gate on the Rust side.
jax = pytest.importorskip("jax", reason="jax/Pallas unavailable — AOT layer is optional")

import jax.numpy as jnp  # noqa: E402

from compile import aot, model, shapes  # noqa: E402


def test_boruvka_step_shapes_and_dtypes():
    n, d = 64, 8
    pts = jnp.zeros((n, d), jnp.float32)
    comps = jnp.zeros((n,), jnp.int32)
    dist, idx = model.boruvka_step(pts, comps)
    assert dist.shape == (n,) and dist.dtype == jnp.float32
    assert idx.shape == (n,) and idx.dtype == jnp.int32


def test_pairwise_matrix_is_tuple():
    out = model.pairwise_matrix(jnp.zeros((64, 8), jnp.float32))
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (64, 64)


@pytest.mark.parametrize("n,d", [(64, 8), (128, 32)])
def test_cheapest_edge_lowers_to_hlo_text(n, d):
    text = aot.lower_cheapest_edge(n, d)
    assert "HloModule" in text
    # the masked-min structure should show up as minimum/compare ops
    assert "minimum" in text
    assert f"f32[{n},{d}]" in text


def test_pairwise_lowers_to_hlo_text():
    text = aot.lower_pairwise(64, 8)
    assert "HloModule" in text
    assert "f32[64,64]" in text


def test_quick_build_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as td:
        out = pathlib.Path(td) / "arts"
        aot.build(out, quick=True, force=False)
        manifest = (out / "manifest.txt").read_text()
        lines = [l for l in manifest.splitlines() if l and not l.startswith("#")]
        assert len(lines) == len(aot.QUICK_BUCKETS["cheapest_edge"]) + len(
            aot.QUICK_BUCKETS["pairwise"]
        )
        for line in lines:
            kernel, n, d, fname = line.split()
            path = out / fname
            assert path.is_file(), fname
            assert int(n) > 0 and int(d) > 0
            assert "HloModule" in path.read_text()[:200]
        # incremental rebuild is a no-op (files kept, manifest rewritten)
        mtimes = {p.name: p.stat().st_mtime_ns for p in out.glob("*.hlo.txt")}
        aot.build(out, quick=True, force=False)
        for p in out.glob("*.hlo.txt"):
            assert mtimes[p.name] == p.stat().st_mtime_ns, "no rebuild expected"


def test_lowered_hlo_matches_eager_numerics():
    """Round-trip: execute the lowered-to-HLO computation via jax's own CPU
    client and compare to the eager kernel — the same check load_hlo.rs does
    from Rust."""
    from jax._src.lib import xla_client as xc

    n, d = 64, 8
    rng = np.random.default_rng(3)
    x = rng.normal(size=(n, d)).astype(np.float32)
    comps = (np.arange(n) % 4).astype(np.int32)

    lowered = jax.jit(model.boruvka_step).lower(
        jax.ShapeDtypeStruct((n, d), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    compiled = lowered.compile()
    got_d, got_i = compiled(jnp.asarray(x), jnp.asarray(comps))
    want_d, want_i = model.boruvka_step(jnp.asarray(x), jnp.asarray(comps))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d), rtol=1e-6)


def test_bucket_tables_sane():
    # blocks are clamped to min(n, BLOCK); the clamp must always divide n
    for n, d in shapes.cheapest_edge_buckets():
        assert n % min(n, shapes.ROW_BLOCK) == 0
        assert n % min(n, shapes.COL_BLOCK) == 0
    assert (2048, 768) in shapes.cheapest_edge_buckets()
    assert len(set(shapes.cheapest_edge_buckets())) == len(
        shapes.cheapest_edge_buckets()
    )
